"""Socket fabric (ISSUE 9): framed transport, partitions, degraded exchange.

Covers the tentpole's five layers plus its satellites:

* the framed wire protocol: round-trip, truncation at every byte offset
  and garbled headers map to ``EOFError``/``FrameError`` (an ``OSError``
  subclass — the process backend's existing death path), never a raw
  ``struct.error`` (property-tested under hypothesis when available);
* the PR-8 death matrix (kill/hang x narrow/shuffle/cross-segment) re-run
  on ``transport="socket"``, with the pipe transport retained as a
  byte-identical oracle;
* per-host partition quorum: a ChaosProxy partition silences a whole
  host, the liveness monitor declares it as a unit, the stream replays on
  survivors exactly-once;
* degraded-mode exchange: a shuffle whose producer and consumer sit on
  different simulated hosts rides the streamed peer-fetch path
  (``kind="stream"`` refs, consume-on-read) and still commits bytes
  identical to the pipe oracle;
* satellites: store-RPC traffic refreshes the heartbeat (a saturated
  worker is NOT a dead worker), remote-host executors skip the local shm
  sweep and count it, and the socket chaos soak with a scheduled
  partition passes the full exactly-once audit.
"""
import glob
import os
import socket
import time
import zlib

import pytest

from repro.core import (DataAccess, DataStore, IngestPlan,
                        StreamingRuntimeEngine, chain_stage, create_stage,
                        resolve_op)
from repro.core.chaos import ChaosEvent, ChaosPlan, chaos_soak
from repro.core.exchange import write_partition_file
from repro.core.items import IngestItem, sweep_pid_segments
from repro.core.procexec import ProcessNodeExecutor
from repro.core.transport import (HEADER_SIZE, ChaosProxy, FramedConnection,
                                  FrameError, FrameListener,
                                  PartitionStreamServer, SendTimeout,
                                  connect_framed, fetch_stream_bytes,
                                  pack_frame, unpack_header)
from repro.data.generators import gen_lineitem

NODES = ["n0", "n1", "n2", "n3"]
HOSTS = {"n0": "hostA", "n1": "hostA", "n2": "hostB", "n3": "hostB"}
ROWS = 100
EPOCH_ITEMS = 4
EPOCH_ROWS = EPOCH_ITEMS * ROWS


def narrow_plan(ds):
    p = IngestPlan("narrow3")
    s1 = p.add_statement([resolve_op("identity_parser")], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar")],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shuffled_plan(ds):
    p = IngestPlan("shuf")
    s1 = p.add_statement([
        resolve_op("identity_parser"),
        resolve_op("partition", scheme="hash", key="orderkey",
                   num_partitions=4),
        resolve_op("map", fn="repro.core.ops_select:identity_columns",
                   shuffle_by="partition"),
    ], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar")],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shard_source(n_shards, rows=ROWS, delay_s=0.0):
    for i in range(n_shards):
        if delay_s:
            time.sleep(delay_s)
        yield IngestItem(gen_lineitem(rows, seed=i))


def shm_segments():
    return set(glob.glob("/dev/shm/psm_*"))


def assert_clean(ds, before_shm):
    assert not os.listdir(ds.dfs_dir)
    assert ds.gc_orphans() == []
    assert shm_segments() - before_shm == set()


def read_rows(ds):
    cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
    return len(cols["quantity"])


def payload_hashes(ds):
    import hashlib
    return sorted(hashlib.sha256(ds.read_payload(e.block_id)).hexdigest()
                  for e in ds.blocks() if not e.is_parity)


def arm_signal(eng, fault, stage, state):
    def hook(rnd, src):
        if rnd.stage == stage and rnd.epoch >= 1 and not state.get("victim"):
            state["victim"] = src
            ex = eng.executor(src)
            (ex.kill if fault == "kill" else ex.hang)()
    eng.shuffle.test_on_manifest = hook


def recv_of(frame_bytes, idle_timeout_s=0.5):
    """Feed raw bytes to a FramedConnection and return what recv() does:
    the object, or the exception instance it raised."""
    a, b = socket.socketpair()
    conn = FramedConnection(b, idle_timeout_s=idle_timeout_s)
    try:
        a.sendall(frame_bytes)
        a.close()
        try:
            return conn.recv()
        except Exception as e:       # noqa: BLE001 — the type IS the assert
            return e
    finally:
        conn.close()


# ---------------------------------------------------------------------------
class TestFrameProtocol:
    def test_round_trip(self):
        payload = b"x" * 57
        frame = pack_frame(payload)
        length, crc = unpack_header(frame[:HEADER_SIZE])
        assert length == 57 and crc == zlib.crc32(payload)
        assert frame[HEADER_SIZE:] == payload

    def test_connection_round_trips_objects(self):
        obj = {"job": ("stage", ["a", "b"]), "n": 3}
        assert recv_of(pack_frame(__import__("pickle").dumps(obj))) == obj

    def test_frame_error_is_oserror(self):
        """The whole failure mapping rests on this: the process backend's
        ``except (EOFError, OSError)`` death path must catch every frame
        fault, send timeouts included."""
        assert issubclass(FrameError, OSError)
        assert issubclass(SendTimeout, FrameError)

    def test_truncation_at_every_offset_never_structerror(self):
        """A peer dying mid-frame at ANY byte offset maps to EOFError (a
        clean boundary) or FrameError (mid-frame) — the torn frame can
        never surface as an unhandled struct.error or a hang."""
        frame = pack_frame(b"hello world, framed")
        for cut in range(len(frame)):
            out = recv_of(frame[:cut])
            if cut == 0:
                assert isinstance(out, EOFError)
            else:
                assert isinstance(out, FrameError), (cut, out)

    def test_garbled_header_every_byte_maps_to_frame_error(self):
        frame = bytearray(pack_frame(b"payload-bytes"))
        for i in range(HEADER_SIZE):
            bad = bytearray(frame)
            bad[i] ^= 0xFF
            with pytest.raises(FrameError):
                unpack_header(bytes(bad[:HEADER_SIZE]))

    def test_garbled_payload_fails_crc(self):
        frame = bytearray(pack_frame(b"payload-bytes"))
        frame[-1] ^= 0xFF
        assert isinstance(recv_of(bytes(frame)), FrameError)

    def test_insane_length_rejected_before_allocation(self):
        from repro.core.transport import (FRAME_MAGIC, FRAME_VERSION,
                                          MAX_FRAME_BYTES, _HDR, _HDR_CRC)
        hdr = _HDR.pack(FRAME_MAGIC, FRAME_VERSION, 0, 0,
                        MAX_FRAME_BYTES + 1, 0)
        raw = hdr + _HDR_CRC.pack(zlib.crc32(hdr))
        with pytest.raises(FrameError):
            unpack_header(raw)

    def test_property_truncation_and_bitflips(self):
        hyp = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=60, deadline=None)
        @given(payload=st.binary(min_size=0, max_size=200),
               data=st.data())
        def prop(payload, data):
            frame = pack_frame(payload)
            cut = data.draw(st.integers(0, len(frame)))
            out = recv_of(frame[:cut])
            if cut == len(frame):
                assert not isinstance(out, Exception) or payload == b""
            elif cut == 0:
                assert isinstance(out, EOFError)
            else:
                assert isinstance(out, (EOFError, FrameError))
            flip = data.draw(st.integers(0, HEADER_SIZE - 1))
            bad = bytearray(frame[:HEADER_SIZE])
            bad[flip] ^= data.draw(st.integers(1, 255))
            with pytest.raises(FrameError):
                unpack_header(bytes(bad))

        prop()


# ---------------------------------------------------------------------------
class TestHandshake:
    def test_hello_round_trip_carries_role_node_info(self):
        lst = FrameListener()
        try:
            conn = connect_framed(lst.address, role="ctrl", node="n7",
                                  token="tok", info={"k": 1})
            acc, role, node, info = lst.accept_framed("tok", timeout_s=5)
            assert (role, node, info) == ("ctrl", "n7", {"k": 1})
            conn.send({"x": 2})
            assert acc.recv() == {"x": 2}
            conn.close()
            acc.close()
        finally:
            lst.close()

    def test_bad_token_dropped_not_accepted(self):
        lst = FrameListener()
        try:
            c = connect_framed(lst.address, role="ctrl", node="n0",
                               token="WRONG")
            with pytest.raises(TimeoutError):
                lst.accept_framed("right", timeout_s=0.6)
            c.close()
        finally:
            lst.close()

    def test_connect_gives_up_after_bounded_attempts(self):
        # reserve a port, release it, dial it while nothing listens
        probe = socket.create_server(("127.0.0.1", 0))
        addr = probe.getsockname()[:2]
        probe.close()
        with pytest.raises(OSError):
            connect_framed(addr, token="t", attempts=2, base_delay_s=0.01,
                           connect_timeout_s=0.2)


# ---------------------------------------------------------------------------
class TestPartitionStreamServer:
    def test_fetch_consumes_the_spill(self, tmp_path):
        root = str(tmp_path)
        srv = PartitionStreamServer(root)
        try:
            path = os.path.join(root, "part.bin")
            items = [IngestItem(gen_lineitem(10, seed=1))]
            write_partition_file(path, items)
            raw = open(path, "rb").read()
            got = fetch_stream_bytes(srv.endpoint, path)
            assert got == raw
            assert not os.path.exists(path)      # consume-on-read
            assert fetch_stream_bytes(srv.endpoint, path) is None
            assert srv.served == 1 and srv.served_bytes == len(raw)
        finally:
            srv.close()

    def test_paths_outside_root_refused(self, tmp_path):
        inner = tmp_path / "inner"
        inner.mkdir()
        secret = tmp_path / "secret.txt"
        secret.write_bytes(b"no")
        srv = PartitionStreamServer(str(inner))
        try:
            assert fetch_stream_bytes(srv.endpoint, str(secret)) is None
            assert secret.exists()
        finally:
            srv.close()

    def test_unreachable_endpoint_returns_none(self, tmp_path):
        probe = socket.create_server(("127.0.0.1", 0))
        addr = probe.getsockname()[:2]
        probe.close()
        assert fetch_stream_bytes(addr, str(tmp_path / "x"),
                                  attempts=1, timeout_s=0.3) is None


# ---------------------------------------------------------------------------
class TestSocketTransportBasic:
    def test_socket_run_byte_identical_to_pipe_oracle(self, tmp_path):
        """Same shards, same plan: the socket fabric must commit exactly
        the pipe transport's bytes — the fabric moves messages, it never
        touches data."""
        results = {}
        for transport in ("pipe", "socket"):
            ds = DataStore(str(tmp_path / transport), nodes=NODES)
            eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                         queue_capacity=8, backend="process",
                                         transport=transport)
            rep = eng.run_stream(narrow_plan(ds), shard_source(8))
            eng.close()
            assert rep.committed_epoch_ids() == [0, 1]
            assert read_rows(ds) == 8 * ROWS
            results[transport] = payload_hashes(ds)
        assert results["socket"] == results["pipe"]

    def test_executor_exposes_worker_exchange_endpoint(self, store):
        ex = ProcessNodeExecutor("n0", store, transport="socket")
        try:
            assert ex.exchange_endpoint is not None
            host, port = ex.exchange_endpoint
            assert host == "127.0.0.1" and port > 0
            ex.send_ping()
            deadline = time.monotonic() + 5
            while ex.heartbeat_age() > 0.5 and time.monotonic() < deadline:
                time.sleep(0.02)
            assert ex.heartbeat_age() < 5      # the pong came back framed
        finally:
            ex.shutdown()

    def test_invalid_transport_rejected(self, store):
        with pytest.raises(ValueError):
            ProcessNodeExecutor("n0", store, transport="carrier-pigeon")
        with pytest.raises(ValueError):
            StreamingRuntimeEngine(store, transport="carrier-pigeon")


# ---------------------------------------------------------------------------
class TestSocketDeathMatrix:
    """The PR-8 matrix, re-run on the socket fabric: a worker death must
    surface through the framed protocol (EOF / FrameError -> WorkerDeath)
    exactly as it did through the pipe, with the same exactly-once
    guarantees and zero leaks."""

    MATRIX = [(edge, fault)
              for edge in ("narrow", "shuffle", "cross-segment")
              for fault in ("kill", "hang")]

    @pytest.mark.parametrize("edge,fault", MATRIX)
    def test_death_matrix_on_socket(self, tmp_path, edge, fault):
        before = shm_segments()
        ds = DataStore(str(tmp_path / f"{edge}-{fault}"), nodes=NODES)
        plan = shuffled_plan(ds) if edge == "shuffle" else narrow_plan(ds)
        hb = dict(heartbeat_interval_s=0.05, heartbeat_miss=3) \
            if fault == "hang" else {}
        eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend="process",
                                     transport="socket", **hb)
        eng.prewarm_executors()
        state = {}
        stage = "b" if edge == "cross-segment" else "a"
        arm_signal(eng, fault, stage, state)
        rep = eng.run_stream(plan, shard_source(16, delay_s=0.01))
        eng.close()
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        victim = state["victim"]
        assert victim and victim in rep.node_failures
        assert read_rows(ds) == 16 * ROWS
        if edge == "shuffle":
            assert rep.cone_replays() == 0
        if fault == "hang":
            assert [d for d in rep.liveness_deaths if d[0] == victim]
        assert_clean(ds, before)

    def test_kill_recovery_byte_identical_to_pipe_oracle(self, tmp_path):
        """A SIGTERM mid-stream on each transport: recovery replays may
        place blocks differently, but the committed payload multiset must
        be identical — the socket fabric's death path loses nothing the
        pipe's kept."""
        results = {}
        for transport in ("pipe", "socket"):
            ds = DataStore(str(tmp_path / f"kill-{transport}"), nodes=NODES)
            eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                         queue_capacity=8, backend="process",
                                         transport=transport)
            eng.prewarm_executors()
            state = {}
            arm_signal(eng, "kill", "a", state)
            rep = eng.run_stream(narrow_plan(ds),
                                 shard_source(16, delay_s=0.01))
            eng.close()
            assert state["victim"] in rep.node_failures
            assert read_rows(ds) == 16 * ROWS
            results[transport] = payload_hashes(ds)
        assert results["socket"] == results["pipe"]


# ---------------------------------------------------------------------------
class TestHostPartitionQuorum:
    def test_partitioned_host_declared_as_unit_and_stream_recovers(
            self, tmp_path):
        """ChaosProxy silences both hostB workers at once: their
        heartbeats miss *together*, the per-host quorum declares the host
        partitioned as one unit, and the stream replays their work on the
        hostA survivors — exactly-once, no leaks."""
        before = shm_segments()
        ds = DataStore(str(tmp_path / "part"), nodes=NODES)
        interval, miss = 0.05, 3
        eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend="process",
                                     transport="socket", node_hosts=HOSTS,
                                     network_chaos=True,
                                     heartbeat_interval_s=interval,
                                     heartbeat_miss=miss)
        eng.prewarm_executors()
        state = {}

        def hook(rnd, src):
            if (rnd.epoch >= 1 and HOSTS[src] == "hostB"
                    and not state.get("fired")):
                state["fired"] = True
                for n, h in HOSTS.items():
                    if h == "hostB":
                        eng.executor(n).net_partition()
        eng.shuffle.test_on_manifest = hook

        rep = eng.run_stream(narrow_plan(ds), shard_source(16, delay_s=0.01))
        eng.close()
        assert state.get("fired")
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        # the quorum saw the host go down as a unit, not two point deaths
        assert rep.host_partitions, "no host-level partition was declared"
        host, members, age = rep.host_partitions[0]
        assert host == "hostB" and sorted(members) == ["n2", "n3"]
        assert age > interval * miss
        # both members were declared dead together; which of them a later
        # dispatch trips over first (surfacing in node_failures) is timing
        assert {d[0] for d in rep.liveness_deaths} == {"n2", "n3"}
        assert rep.node_failures
        assert set(rep.node_failures) <= {"n2", "n3"}
        assert read_rows(ds) == 16 * ROWS
        assert_clean(ds, before)


# ---------------------------------------------------------------------------
class TestDegradedExchange:
    def test_cross_host_shuffle_streams_and_matches_pipe_oracle(
            self, tmp_path):
        """Producer and consumer on different simulated hosts: the shuffle
        partition rides the streamed peer-fetch path (kind="stream",
        consume-on-read) instead of assuming a shared /dev/shm — and the
        committed bytes still equal the pipe oracle's."""
        before = shm_segments()
        results = {}
        for mode in ("pipe", "socket"):
            ds = DataStore(str(tmp_path / f"dx-{mode}"), nodes=NODES)
            kw = {}
            if mode == "socket":
                kw = dict(transport="socket", node_hosts=HOSTS)
            eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                         queue_capacity=8, backend="process",
                                         **kw)
            rep = eng.run_stream(shuffled_plan(ds), shard_source(8))
            eng.close()
            assert rep.committed_epoch_ids() == [0, 1]
            assert read_rows(ds) == 8 * ROWS
            if mode == "socket":
                assert rep.degraded_exchange_rounds() >= 1, \
                    "cross-host shuffle never took the streamed path"
                assert rep.degraded_peer_bytes() > 0
            results[mode] = payload_hashes(ds)
            assert_clean(ds, before)
        assert results["socket"] == results["pipe"]


# ---------------------------------------------------------------------------
class TestLivenessUnderLoad:
    def test_saturated_worker_outlives_the_miss_window(self, tmp_path):
        """Satellite (a): a worker too busy to answer pings — but still
        issuing store RPCs — must NOT be declared dead.  The stall runs
        ~3x the miss window while store traffic keeps the beat fresh."""
        ds = DataStore(str(tmp_path / "busy"), nodes=NODES)
        interval, miss = 0.05, 3
        eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend="process",
                                     heartbeat_interval_s=interval,
                                     heartbeat_miss=miss)
        eng.prewarm_executors()
        state = {}

        def hook(rnd, src):
            if rnd.epoch == 0 and not state.get("stalled"):
                state["stalled"] = src
                eng.executor(src).stall_recv(3 * interval * miss,
                                             rpc_every=interval / 2)
        eng.shuffle.test_on_manifest = hook

        rep = eng.run_stream(narrow_plan(ds), shard_source(8))
        eng.close()
        assert state.get("stalled")
        assert rep.committed_epoch_ids() == [0, 1]
        assert rep.liveness_deaths == []        # the fix under test
        assert not rep.node_failures
        assert read_rows(ds) == 8 * ROWS


# ---------------------------------------------------------------------------
class TestRemoteSweepScoping:
    def test_remote_executor_skips_local_shm_sweep(self, store):
        """Satellite (b): a pid-prefix sweep on THIS host can only ever
        name local segments — for a remote worker it must skip (and
        count) instead of silently no-opping."""
        ex = ProcessNodeExecutor("n0", store, host="far-host",
                                 local_worker=False)
        try:
            assert ex.host == "far-host"
        finally:
            ex.shutdown()
        assert ex.sweep_skips >= 1

    def test_local_executor_sweeps(self, store):
        ex = ProcessNodeExecutor("n0", store)
        ex.shutdown()
        assert ex.sweep_skips == 0

    def test_sweep_pid_segments_counts_unlinked(self):
        assert sweep_pid_segments(os.getpid()) == 0   # nothing to sweep

    def test_run_report_counts_skips(self, tmp_path):
        ds = DataStore(str(tmp_path / "rr"), nodes=NODES)
        eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend="process",
                                     transport="socket", node_hosts=HOSTS)
        rep = eng.run_stream(narrow_plan(ds), shard_source(8))
        eng.close()
        # simulated hosts fork locally, so every sweep is real — the soak's
        # leak audit depends on this staying 0
        assert rep.sweep_skipped_remote == 0


# ---------------------------------------------------------------------------
class TestChaosNetPlan:
    def test_partition_event_requires_host(self):
        with pytest.raises(ValueError):
            ChaosEvent("partition", 0, "a", "")
        ev = ChaosEvent("partition", 0, "a", "", host="hostA")
        assert ev.host == "hostA"

    def test_partition_consumes_member_count_from_budget(self):
        p = ChaosPlan.generate(9, epochs=10, nodes=NODES, stages=["a", "b"],
                               kills=2, hangs=1, drops=1, partitions=1,
                               hosts=HOSTS)
        parts = [e for e in p.events if e.kind == "partition"]
        lethal = [e for e in p.events
                  if e.kind in ("kill", "hang", "drop")]
        assert len(parts) == 1
        # budget = len(NODES) - 2 = 2; the host's 2 members consume it all
        assert lethal == []

    def test_lethal_victims_avoid_partitioned_hosts(self):
        nodes = [f"n{i}" for i in range(8)]
        hosts = {n: ("hostA" if i < 2 else "hostB")
                 for i, n in enumerate(nodes)}
        p = ChaosPlan.generate(3, epochs=10, nodes=nodes, stages=["a"],
                               kills=2, drops=1, partitions=1, hosts=hosts)
        parts = [e for e in p.events if e.kind == "partition"]
        assert len(parts) == 1
        parted = parts[0].host
        for e in p.events:
            if e.kind in ("kill", "hang", "drop"):
                assert hosts[e.node] != parted

    def test_signal_events_gated_by_transport(self):
        p = ChaosPlan([ChaosEvent("partition", 0, "a", "", host="hostA"),
                       ChaosEvent("drop", 1, "a", "n0"),
                       ChaosEvent("delay_conn", 1, "b", "n1", seconds=0.01),
                       ChaosEvent("hang", 2, "a", "n2"),
                       ChaosEvent("delay", 2, "b", "n3", seconds=0.0)])
        assert [e.kind for e in p.signal_events("thread")] == ["delay"]
        assert sorted(e.kind for e in p.signal_events("process")) \
            == ["delay", "hang"]
        assert sorted(e.kind for e in p.signal_events("process", "socket")) \
            == ["delay", "delay_conn", "drop", "hang", "partition"]

    def test_generation_with_net_events_is_deterministic(self):
        kw = dict(epochs=10, nodes=NODES, stages=["a", "b"], kills=1,
                  partitions=1, drops=1, conn_delays=1, hosts=HOSTS)
        assert (ChaosPlan.generate(5, **kw).events
                == ChaosPlan.generate(5, **kw).events)


# ---------------------------------------------------------------------------
class TestSocketChaosSoak:
    def test_socket_soak_with_partition_passes_audit(self):
        """The acceptance soak: chaotic epochs on the socket fabric with a
        scheduled whole-host partition — exactly-once commits, the quorum
        declared the host, zero leaked segments / spool / spills."""
        res = chaos_soak(backend="process", transport="socket", epochs=12,
                         partitions=1)
        assert res.ok, res.errors
        assert res.transport == "socket"
        assert res.partitions_fired >= 1
        assert res.host_partitions >= 1
        assert res.rows_in == res.rows_out
        assert res.orphans == [] and res.shm_leaked == []
        assert res.spill_leaked == []

    def test_socket_soak_rejects_thread_backend(self):
        with pytest.raises(ValueError):
            chaos_soak(backend="thread", transport="socket")

    @pytest.mark.slow
    def test_socket_soak_full_scale_with_drops(self):
        res = chaos_soak(backend="process", transport="socket", epochs=20,
                         partitions=1, drops=1, conn_delays=1, nodes=6)
        assert res.ok, res.errors
        assert res.partitions_fired >= 1
