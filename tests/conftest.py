import os
import sys

# repo-local imports without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def store(tmp_path):
    from repro.core import DataStore
    return DataStore(str(tmp_path / "store"), nodes=["n0", "n1", "n2", "n3"])
