"""Runtime engine: parallelism, work stealing, in-flight + post-ingestion FT."""
import numpy as np
import pytest

from repro.core import (Catalog, DataAccess, DataStore, ErasureRecovery,
                        FaultInjection, FaultToleranceDaemon, IngestPlan,
                        ReplicationRecovery, RuntimeEngine,
                        TransformationRecovery, create_stage, format_, select)
from repro.core import store as store_stmt
from repro.data.generators import as_file_items, gen_lineitem


def simple_plan(ds, *, replicas=1, serialize="columnar", erasure=None):
    p = IngestPlan("t")
    s1 = select(p, replicate=replicas if replicas > 1 else None)
    fmt = {"chunk": {"target_rows": 512}, "serialize": serialize}
    if erasure:
        fmt["erasure"] = erasure
    s2 = format_(p, s1, **fmt)
    s3 = store_stmt(p, s2, locate="roundrobin",
                    locate_args={"num_locations": len(ds.nodes)}, upload=ds)
    create_stage(p, using=[s1, s2, s3], name="main")
    return p


class TestParallelIngestion:
    def test_work_stealing_distributes_shards(self, store):
        eng = RuntimeEngine(store)
        items = as_file_items(gen_lineitem(4000), shards=16)
        rep = eng.run(simple_plan(store), items)  # list -> shared queue
        assert sum(rep.per_node_shards.values()) == 16
        assert all(v > 0 for v in rep.per_node_shards.values())

    def test_per_node_sources(self, store):
        eng = RuntimeEngine(store)
        items = as_file_items(gen_lineitem(2000), shards=4)
        rep = eng.run(simple_plan(store), {"n0": items[:2], "n2": items[2:]})
        assert rep.per_node_shards["n0"] == 2 and rep.per_node_shards["n2"] == 2
        assert rep.per_node_shards["n1"] == 0


class TestInFlightFT:
    def test_operator_failure_retries_from_checkpoint(self, store):
        eng = RuntimeEngine(store, max_retries=3)
        items = as_file_items(gen_lineitem(1000), shards=4)
        faults = FaultInjection(op_failures={("main", 0): 2})  # fails twice
        rep = eng.run(simple_plan(store), items, faults=faults)
        assert rep.op_failures  # observed
        assert not rep.dummy_substitutions  # recovered before 3 strikes
        assert store.blocks()

    def test_repeated_failure_installs_dummy_op(self, store):
        eng = RuntimeEngine(store, max_retries=3)
        items = as_file_items(gen_lineitem(1000), shards=4)
        faults = FaultInjection(op_failures={("main", 1): 99})
        rep = eng.run(simple_plan(store), items, faults=faults)
        assert rep.dummy_substitutions  # paper: dummy pass-through after 3

    def test_node_failure_reassigns_shards(self, store):
        eng = RuntimeEngine(store)
        items = as_file_items(gen_lineitem(2000), shards=8)
        faults = FaultInjection(node_death_after_stage={"n1": "main"})
        rep = eng.run(simple_plan(store), items, faults=faults)
        assert "n1" in rep.node_failures


class TestPostIngestionFT:
    def _ingest(self, ds, **kw):
        eng = RuntimeEngine(ds)
        eng.run(simple_plan(ds, **kw), as_file_items(gen_lineitem(2000), 4))

    def test_replication_recovery(self, store):
        self._ingest(store, replicas=2)
        victim = next(e for e in store.blocks() if e.replica_index == 0)
        store.corrupt_block(victim.block_id)
        daemon = FaultToleranceDaemon(store, [ReplicationRecovery()])
        rep = daemon.sweep()
        assert rep.recovered and not rep.unrecoverable
        assert store.verify_block(victim.block_id)

    def test_transformation_recovery_reencodes_layout(self, tmp_path):
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
        p = IngestPlan("t")
        s1 = select(p, replicate=2, replicate_tag="rep")
        s2 = format_(p, s1, chunk={"target_rows": 512}, serialize="columnar")
        s3 = format_(p, s1, chunk={"target_rows": 512}, serialize="row")
        s4 = store_stmt(p, s2, s3, upload=ds)
        create_stage(p, using=[s1], name="a")
        from repro.core import chain_stage
        chain_stage(p, to=["a"], using=[s2], where={"rep": 1}, name="b")
        chain_stage(p, to=["a"], using=[s3], where={"rep": 2}, name="c")
        chain_stage(p, to=["b", "c"], using=[s4], name="d")
        RuntimeEngine(ds).run(p, as_file_items(gen_lineitem(1500), 4))

        victim = next(e for e in ds.blocks() if e.layout == "columnar")
        ds.corrupt_block(victim.block_id)
        daemon = FaultToleranceDaemon(ds, [TransformationRecovery()])
        rep = daemon.sweep()
        assert rep.recovered
        assert ds.verify_block(victim.block_id)
        # layout restored as columnar, not as the donor's layout
        assert next(e for e in ds.blocks()
                    if e.block_id == victim.block_id).layout == "columnar"

    def test_erasure_recovery(self, store):
        self._ingest(store, erasure={"k": 4, "m": 2})
        striped = [e for e in store.blocks() if e.stripe_id]
        victim = striped[0]
        store.corrupt_block(victim.block_id)
        daemon = FaultToleranceDaemon(store, [ErasureRecovery()])
        rep = daemon.sweep()
        assert rep.recovered and store.verify_block(victim.block_id)

    def test_catalog_reinstantiates_plan_and_udfs(self, store):
        p = simple_plan(store)
        cat = Catalog(store)
        cat.register_plan(p, recovery_udfs=["replication"])
        cat2 = Catalog(store)  # fresh load from disk
        sig = cat2.plan_signature(p.name)
        assert sig["statements"]
        chain = cat2.recovery_chain(p.name)
        assert len(chain) == 1
