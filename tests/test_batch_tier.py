"""Kernel-backed batch operator tier (ISSUE 7).

The scalar iterator path is the correctness oracle: every test here pins the
vectorized ``process_batch`` implementations (stacked GF(256) erasure encode,
batch serialize/pack), the ``VectorizeRule`` block selection, and the
runtime integration on both node backends against it — plus the satellite
regressions (``num_threads`` across clone/pickle, deque pending buffer,
pool reuse across ``set_input`` calls).
"""
import copy
import pickle
import threading
from collections import deque

import numpy as np
import pytest

from repro.core import (BatchFallback, DataAccess, DataStore, FaultInjection,
                        IngestionOptimizer, IngestPlan, RuntimeEngine,
                        StreamingRuntimeEngine, VectorizeRule, chain_stage,
                        create_stage, resolve_op, run_ops_batched, select)
from repro.core.items import Granularity, IngestItem
from repro.core.operators import IngestOp, OpMode
from repro.core.ops_format import PackOp, SerializeOp
from repro.core.ops_store import ErasureOp
from repro.data.generators import as_file_items, gen_lineitem
from repro.erasure import ReedSolomon
from repro.erasure.gf256 import GF256


def _blocks(rng, n, lo=1, hi=5000):
    """Random BLOCK items with ragged (often odd) payload lengths."""
    return [IngestItem(rng.integers(0, 256, size=int(rng.integers(lo, hi)),
                                    dtype=np.uint8).tobytes(),
                       Granularity.BLOCK, (), {}) for _ in range(n)]


def _norm(item):
    """Stripe ids embed a per-instance nonce; strip it so two operator
    instances' outputs compare equal."""
    meta = dict(item.meta)
    if "stripe_id" in meta:
        meta["stripe_id"] = meta["stripe_id"].rsplit("-", 1)[-1]
    data = item.data
    if isinstance(data, np.ndarray):
        data = data.tobytes()
    return (bytes(data) if isinstance(data, (bytes, bytearray)) else data,
            item.labels, meta)


# ---------------------------------------------------------------------------
class TestGF256Tables:
    def test_row_table_matches_mul(self, rng):
        b = np.arange(256, dtype=np.uint8)
        for c in (0, 1, 2, 7, 128, 255):
            np.testing.assert_array_equal(GF256.row_table(c),
                                          GF256.mul(np.uint8(c), b))

    def test_pair_table_packs_two_products(self):
        t = GF256.pair_table(29)
        row = GF256.row_table(29)
        idx = np.arange(65536, dtype=np.uint32)
        np.testing.assert_array_equal(t & 0xFF, row[idx & 0xFF])
        np.testing.assert_array_equal(t >> 8, row[idx >> 8])

    @pytest.mark.parametrize("n", [0, 1, 2, 3, 64, 777])
    def test_xor_mul_into_matches_scalar(self, rng, n):
        for c in (0, 3, 91, 255):
            payload = rng.integers(0, 256, n, dtype=np.uint8)
            acc = rng.integers(0, 256, max(n, 1), dtype=np.uint8)
            expect = acc.copy()
            expect[:n] ^= GF256.mul(np.uint8(c), payload)
            GF256.xor_mul_into(acc, c, payload)
            np.testing.assert_array_equal(acc, expect)

    def test_xor_mul_into_unaligned_slice(self, rng):
        # odd-offset slice of a larger buffer: uint16 view would raise
        buf = rng.integers(0, 256, 1025, dtype=np.uint8)
        payload = buf[1:]
        acc = np.zeros(len(payload), dtype=np.uint8)
        GF256.xor_mul_into(acc, 7, payload)
        np.testing.assert_array_equal(acc, GF256.mul(np.uint8(7), payload))


class TestBatchEncode:
    @pytest.mark.parametrize("k,m", [(4, 2), (10, 3)])
    def test_matches_per_stripe_oracle(self, rng, k, m):
        rs = ReedSolomon(k, m)
        stripes = [[rng.integers(0, 256, int(rng.integers(1, 3000)),
                                 dtype=np.uint8) for _ in range(k)]
                   for _ in range(5)]
        batched = rs.encode_payload_batch(stripes)
        for payloads, (parity, pad) in zip(stripes, batched):
            exp_parity, exp_pad = rs.encode_payloads(
                [p.tobytes() for p in payloads])
            assert pad == exp_pad
            np.testing.assert_array_equal(parity, exp_parity)

    def test_interpret_mode_kernel_on_stacked_matrix(self, rng):
        """The pallas path's stacked ``(m x k) @ (k x S*L)`` contraction vs
        the kernels/ref.py table oracle (interpret mode off-TPU)."""
        import jax.numpy as jnp

        from repro.kernels import ref
        from repro.kernels.ops import gf256_matmul
        k, m, S, L = 5, 3, 4, 256
        rs = ReedSolomon(k, m)
        data = rng.integers(0, 256, (k, S * L), dtype=np.uint8)
        out = np.asarray(gf256_matmul(jnp.asarray(rs.C), jnp.asarray(data),
                                      block_n=512))
        np.testing.assert_array_equal(out, ref.gf256_matmul_ref(rs.C, data))

    def test_use_pallas_batch_matches_numpy_batch(self, rng):
        k, m = 4, 2
        stripes = [[rng.integers(0, 256, 300, dtype=np.uint8)
                    for _ in range(k)] for _ in range(3)]
        plain = ReedSolomon(k, m).encode_payload_batch(copy.deepcopy(stripes))
        pallas = ReedSolomon(k, m, use_pallas=True).encode_payload_batch(
            copy.deepcopy(stripes))
        for (pa, la), (pb, lb) in zip(plain, pallas):
            assert la == lb
            np.testing.assert_array_equal(pa, pb)


# ---------------------------------------------------------------------------
class TestErasureOpBatch:
    @pytest.mark.parametrize("n", [1, 4, 11, 23])
    def test_byte_identical_to_scalar_oracle(self, rng, n):
        items = _blocks(rng, n)
        scalar = ErasureOp(k=4, m=2).run([copy.deepcopy(i) for i in items])
        batch = ErasureOp(k=4, m=2).run_batch(
            [copy.deepcopy(i) for i in items])
        assert [_norm(x) for x in scalar] == [_norm(x) for x in batch]

    def test_trailing_partial_stripe_drained(self, rng):
        op = ErasureOp(k=4, m=2)
        out = op.run_batch(_blocks(rng, 6))   # 1 full + 1 partial stripe
        assert len(out) == 6 + 2 * 2
        assert not op._stripe                 # nothing left buffered
        metas = [it.meta for it in out]
        assert {m["stripe_id"] for m in metas} == {
            metas[0]["stripe_id"], metas[-1]["stripe_id"]}

    def test_use_pallas_op_matches_scalar(self, rng):
        items = _blocks(rng, 9)
        scalar = ErasureOp(k=4, m=2).run([copy.deepcopy(i) for i in items])
        batch = ErasureOp(k=4, m=2, use_pallas=True).run_batch(
            [copy.deepcopy(i) for i in items])
        assert [_norm(x) for x in scalar] == [_norm(x) for x in batch]
        assert ErasureOp(k=4, m=2, use_pallas=True).rs._pallas_matmul

    def test_unsupported_payload_raises_fallback(self):
        op = ErasureOp(k=2, m=1)
        items = [IngestItem({"x": np.arange(4)}, Granularity.BLOCK, (), {}),
                 IngestItem(b"ok", Granularity.BLOCK, (), {})]
        with pytest.raises(BatchFallback):
            op.process_batch(items)


class TestFormatOpsBatch:
    def _chunks(self, n, rows=64):
        return [IngestItem({"a": np.arange(rows, dtype=np.int64) + i,
                            "b": np.full(rows, float(i))})
                for i in range(n)]

    @pytest.mark.parametrize("layouts", [None, ("columnar", "row")])
    def test_serialize_batch_matches_serial_oracle(self, layouts):
        kw = {"layouts": layouts} if layouts else {}
        oracle = SerializeOp(**kw)
        oracle.mode = OpMode.SERIAL     # the deterministic reference order
        expect = oracle.run(self._chunks(5))
        got = SerializeOp(**kw).run_batch(self._chunks(5))
        assert len(expect) == len(got)
        for e, g in zip(expect, got):
            assert e.labels == g.labels
            assert e.data.tobytes() == g.data.tobytes()

    def test_pack_batch_matches_serial_oracle(self, rng):
        def chunks():
            return [IngestItem({"tokens": np.array(
                [rng.integers(1, 100, int(rng.integers(3, 40)))
                 for _ in range(20)], dtype=object)}) for rng in
                [np.random.default_rng(s) for s in range(4)]]
        oracle = PackOp(seq_len=64, rows_per_block=4)
        oracle.mode = OpMode.SERIAL
        expect = oracle.run(chunks())
        got = PackOp(seq_len=64, rows_per_block=4).run_batch(chunks())
        assert len(expect) == len(got)
        for e, g in zip(expect, got):
            assert e.labels == g.labels
            for key in ("tokens", "loss_mask", "positions", "segment_ids"):
                np.testing.assert_array_equal(e.data[key], g.data[key])


# ---------------------------------------------------------------------------
class TestVectorizeRule:
    def _plan(self, ds):
        p = IngestPlan("v")
        s1 = select(p)
        s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                              resolve_op("serialize", layout="columnar"),
                              resolve_op("erasure", k=4, m=2)],
                             kind="format", inputs=[s1])
        s3 = p.add_statement([resolve_op("upload", store=ds)],
                             kind="store", inputs=[s2])
        create_stage(p, using=[s1], name="a")
        chain_stage(p, to=["a"], using=[s2], name="b")
        chain_stage(p, to=["b"], using=[s3], name="c")
        return p

    def test_selects_all_capable_blocks_only(self, store):
        plans = IngestionOptimizer().optimize(self._plan(store).compile())
        fmt = next(sp for sp in plans if sp.name == "b")
        # [chunk, serialize] shares a block and vectorizes (chunk gained the
        # default-loop batch path with the columnar plane, ISSUE 10);
        # [erasure] stands alone and vectorizes
        assert fmt.batch_blocks == [True, True]
        for sp in plans:
            for blk, on in zip(sp.pipeline_blocks, sp.batch_blocks):
                if on:
                    assert all(sp.ops[i].batch_capable for i in blk)

    def test_disabled_rule_keeps_everything_scalar(self, store):
        opt = IngestionOptimizer(vectorize=VectorizeRule(enabled=False))
        plans = opt.optimize(self._plan(store).compile())
        assert not any(any(sp.batch_blocks) for sp in plans)

    def test_unoptimized_plans_untouched(self, store):
        assert all(sp.batch_blocks == []
                   for sp in self._plan(store).compile())

    def test_batch_blocks_survive_clone_and_pickle(self, store):
        plans = IngestionOptimizer().optimize(self._plan(store).compile())
        fmt = next(sp for sp in plans if sp.name == "b")
        assert fmt.clone().batch_blocks == fmt.batch_blocks
        # upload holds a live store; pickle the format stage only
        assert pickle.loads(pickle.dumps(fmt)).batch_blocks == fmt.batch_blocks


class _FallbackOp(IngestOp):
    name = "fb"
    batch_capable = True

    def process(self, item):
        yield item.with_label(self.name, "scalar")

    def process_batch(self, items):
        raise BatchFallback("no vectorized path for these payloads")


class TestRunOpsBatched:
    def test_fallback_counted_and_output_is_scalar(self, rng):
        out, stats = run_ops_batched([_FallbackOp()], _blocks(rng, 3))
        assert stats["batch_fallbacks"] == 1
        assert [it.label_value("fb") for it in out] == ["scalar"] * 3
        assert stats["vectorized_rows"] == 3

    def test_kernel_time_attributed(self, rng):
        op = ErasureOp(k=4, m=2)
        _, stats = run_ops_batched([op], _blocks(rng, 8))
        assert stats["batch_fallbacks"] == 0
        assert stats["kernel_ms"] >= 0.0
        assert op.kernel_ms_total == pytest.approx(stats["kernel_ms"])


# ---------------------------------------------------------------------------
def erasure_plan(ds):
    p = IngestPlan("bt")
    s1 = select(p)
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar"),
                          resolve_op("erasure", k=4, m=2)],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def stream_plan(ds):
    p = IngestPlan("sbt")
    s1 = p.add_statement([
        resolve_op("identity_parser"),
        resolve_op("partition", scheme="hash", key="orderkey",
                   num_partitions=4),
        resolve_op("map", fn="repro.core.ops_select:identity_columns",
                   shuffle_by="partition"),
    ], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar"),
                          resolve_op("erasure", k=4, m=2)],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


class TestEngineIntegration:
    def test_thread_backend_vectorizes_and_matches_scalar(self, tmp_path):
        rows = {}
        for tag, rule in (("vec", VectorizeRule()),
                          ("scalar", VectorizeRule(enabled=False))):
            ds = DataStore(str(tmp_path / tag), nodes=["n0", "n1"])
            eng = RuntimeEngine(
                ds, optimizer=IngestionOptimizer(vectorize=rule))
            rep = eng.run(erasure_plan(ds),
                          as_file_items(gen_lineitem(2000), shards=4))
            if tag == "vec":
                assert rep.vectorized_rows > 0
                assert rep.batch_fallbacks == 0
            else:
                assert rep.vectorized_rows == 0
            cols = DataAccess(ds).read_all(projection=["quantity"])
            rows[tag] = np.sort(cols["quantity"])
        np.testing.assert_array_equal(rows["vec"], rows["scalar"])

    def test_injected_failure_in_batched_block_retries(self, store):
        eng = RuntimeEngine(store, max_retries=3)
        items = as_file_items(gen_lineitem(1000), shards=4)
        # op index 2 = erasure, the batched block in stage "b"
        faults = FaultInjection(op_failures={("b", 2): 2})
        rep = eng.run(erasure_plan(store), items, faults=faults)
        assert rep.op_failures and not rep.dummy_substitutions
        assert rep.vectorized_rows > 0
        assert store.blocks()

    def test_repeated_failure_installs_dummy_in_batched_block(self, store):
        eng = RuntimeEngine(store, max_retries=3)
        items = as_file_items(gen_lineitem(1000), shards=4)
        faults = FaultInjection(op_failures={("b", 2): 99})
        rep = eng.run(erasure_plan(store), items, faults=faults)
        assert rep.dummy_substitutions
        assert store.blocks()   # dummy pass-through keeps the stage alive

    def test_process_backend_vectorizes_with_zero_coordinator_bytes(
            self, tmp_path):
        rows = {}
        for backend in ("thread", "process"):
            ds = DataStore(str(tmp_path / backend),
                           nodes=["n0", "n1", "n2", "n3"])
            eng = StreamingRuntimeEngine(ds, epoch_items=4, queue_capacity=8,
                                         backend=backend)
            rep = eng.run_stream(
                stream_plan(ds),
                (IngestItem(gen_lineitem(100, seed=i)) for i in range(8)))
            assert rep.vectorized_rows() > 0
            assert rep.batch_fallbacks() == 0
            if backend == "process":
                # batch execution must not re-route item bytes through the
                # coordinator: the resident dataflow invariant holds
                assert sum(e.run.stage_coordinator_bytes
                           for e in rep.epochs) == 0
            cols = DataAccess(ds).since_epoch(-1).read_all(
                projection=["quantity"])
            rows[backend] = np.sort(cols["quantity"])
            eng.close()
        np.testing.assert_array_equal(rows["thread"], rows["process"])


# ---------------------------------------------------------------------------
class TestSatelliteRegressions:
    def test_num_threads_survives_clone_and_pickle(self):
        op = SerializeOp(num_threads=7)
        assert op.num_threads == 7
        assert op.clone().num_threads == 7
        assert pickle.loads(pickle.dumps(op)).num_threads == 7

    def test_pending_buffer_is_deque(self):
        op = SerializeOp()
        assert isinstance(op._pending, deque)
        op.run(self_chunks())
        assert isinstance(op._pending, deque)

    def test_pool_reused_across_set_input_and_joined_on_finalize(self):
        op = SerializeOp(num_threads=2)   # cpu_heavy -> PARALLEL mode
        op.initialize()
        op.set_input(self_chunks())
        while op.has_next():
            op.next()
        pool1 = op._pool
        assert pool1 is not None
        op.set_input(self_chunks())
        while op.has_next():
            op.next()
        assert op._pool is pool1          # no per-batch pool churn
        op.finalize()
        assert op._pool is None
        assert pool1._shutdown


def self_chunks(n=4, rows=32):
    return [IngestItem({"a": np.arange(rows, dtype=np.int64) + i})
            for i in range(n)]
