"""Columnar data plane (ISSUE 10): column buffers across stage edges.

Covers the tentpole's layers plus its satellites:

* optimizer edge eligibility: ``VectorizeRule`` annotates ``StagePlan``
  with columnar-capable edges (producer's last block + consumer's first
  block both batch-capable), and a scalar consumer pins the edge;
* the codec fast paths: shm segments, spill files (``columnar_*``
  naming + magic sniff), and the stream fetch all dispatch on the
  columnar descriptor/magic with no consumer-side changes;
* the scalar path as byte-identical oracle: columnar on vs off commits
  the same payload multiset on both backends, narrow and shuffle edges,
  with all three zero-coordinator-bytes invariants intact;
* fallback sanctity: a non-uniform batch falls back to items per
  producer, flagged on the manifest and counted — never wrong;
* the PR-8 death matrix re-run with columnar edges enabled: kill/hang x
  narrow/shuffle/cross-segment x backend — exactly-once commits,
  cone-replay observables intact, no leaked segments or spills;
* satellites: oversized partitions stream as bounded chunk frames
  (never a spurious FrameError), ``gc_orphans`` reclaims crashed
  ``columnar_*`` spills, kernel-backed PackOp equals the scalar packer,
  and ``columnar_rows_per_s`` is gated by default in perf_gate.
"""
import copy
import glob
import os
import time

import numpy as np
import pytest

from repro.core import (DataAccess, DataStore, IngestPlan,
                        RuntimeEngine, StreamFaultInjection,
                        StreamingRuntimeEngine, chain_stage, create_stage,
                        resolve_op)
from repro.core.exchange import (COLUMNAR_MAGIC, columnar_file_name,
                                 decode_partition, encode_columnar_partition,
                                 is_exchange_file, partition_batch,
                                 partition_items, read_partition_file,
                                 write_columnar_file)
from repro.core.items import (ColumnarBatch, Granularity, IngestItem,
                              decode_items, encode_items)
from repro.core.optimizer import IngestionOptimizer
from repro.core.runtime import ExchangeRound
from repro.core.transport import (PartitionStreamServer, fetch_stream_bytes)
from repro.data.generators import gen_lineitem

NODES = ["n0", "n1", "n2", "n3"]
ROWS = 100
EPOCH_ITEMS = 4
EPOCH_ROWS = EPOCH_ITEMS * ROWS


def narrow_plan(ds):
    p = IngestPlan("narrow3")
    s1 = p.add_statement([resolve_op("identity_parser")], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar")],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shuffled_plan(ds):
    p = IngestPlan("shuf")
    s1 = p.add_statement([
        resolve_op("identity_parser"),
        resolve_op("partition", scheme="hash", key="orderkey",
                   num_partitions=4),
        resolve_op("map", fn="repro.core.ops_select:identity_columns",
                   shuffle_by="partition"),
    ], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar")],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shard_source(n_shards, rows=ROWS, delay_s=0.0):
    for i in range(n_shards):
        if delay_s:
            time.sleep(delay_s)
        yield IngestItem(gen_lineitem(rows, seed=i))


def shm_segments():
    return set(glob.glob("/dev/shm/psm_*"))


def assert_clean(ds, before_shm):
    assert not os.listdir(ds.dfs_dir)
    assert ds.gc_orphans() == []
    assert shm_segments() - before_shm == set()


def read_rows(ds):
    cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
    return len(cols["quantity"])


def payload_hashes(ds):
    import hashlib
    return sorted(hashlib.sha256(ds.read_payload(e.block_id)).hexdigest()
                  for e in ds.blocks() if not e.is_parity)


def arm_signal(eng, fault, stage, state):
    def hook(rnd, src):
        if rnd.stage == stage and rnd.epoch >= 1 and not state.get("victim"):
            state["victim"] = src
            ex = eng.executor(src)
            (ex.kill if fault == "kill" else ex.hang)()
    eng.shuffle.test_on_manifest = hook


def chunk_items(n, rows=8, seed=0):
    rng = np.random.default_rng(seed)
    return [IngestItem({"x": rng.integers(0, 50, rows).astype(np.int64),
                        "y": rng.random(rows).astype(np.float32)},
                       Granularity.CHUNK).with_label("chunk", i)
            for i in range(n)]


# ---------------------------------------------------------------------------
class TestColumnarEdgeAnnotation:
    def test_all_capable_plan_gets_columnar_edges(self, store):
        plans = IngestionOptimizer().optimize(shuffled_plan(store).compile())
        by_name = {sp.name: sp for sp in plans}
        assert by_name["a"].columnar_edges == {"b": True}   # shuffle edge
        assert by_name["b"].columnar_edges == {"c": True}   # cross-segment

    def test_columnar_toggle_clears_edges(self, store):
        opt = IngestionOptimizer()
        opt.vectorize.columnar = False
        plans = opt.optimize(narrow_plan(store).compile())
        assert all(not sp.columnar_edges for sp in plans)

    def test_scalar_consumer_pins_the_edge(self, store):
        """A consumer whose FIRST block is not batch-capable keeps the
        incoming edge item-at-a-time, whatever the producer can do."""
        ds = store
        p = IngestPlan("mixed")
        s1 = p.add_statement([resolve_op("identity_parser")], kind="select")
        s2 = p.add_statement([resolve_op("erasure", k=2, m=1),
                              resolve_op("upload", store=ds)],
                             kind="store", inputs=[s1])
        create_stage(p, using=[s1], name="a")
        chain_stage(p, to=["a"], using=[s2], name="b")
        plans = IngestionOptimizer().optimize(p.compile())
        by_name = {sp.name: sp for sp in plans}
        # erasure is batch-capable but stripe-STATEFUL: the optimizer keeps
        # it scalar-blocked in mixed plans only when its block says so —
        # assert against whatever the block map decided, consistently
        assert by_name["a"].columnar_edges["b"] == bool(
            by_name["b"].batch_blocks and by_name["b"].batch_blocks[0])

    def test_clone_preserves_edges(self, store):
        plans = IngestionOptimizer().optimize(narrow_plan(store).compile())
        for sp in plans:
            assert sp.clone().columnar_edges == sp.columnar_edges

    def test_round_columnar_requires_every_consumer(self):
        rnd = ExchangeRound(xid=0, stage="a", key=None, epoch=-1,
                            targets=["n0"], consumers=["b"], spill_share=1,
                            columnar=True)
        assert rnd.worker_ctx("/tmp")["columnar"] is True
        off = ExchangeRound(xid=1, stage="a", key=None, epoch=-1,
                            targets=["n0"], consumers=["b"], spill_share=1)
        assert "columnar" not in off.worker_ctx("/tmp")


# ---------------------------------------------------------------------------
class TestColumnarCodecs:
    def test_shm_partition_roundtrip(self):
        items = chunk_items(5)
        batch = ColumnarBatch.from_items(items)
        desc, lease = encode_columnar_partition(batch)
        assert desc["kind"] == "shm" and desc["columnar"]
        assert desc["count"] == 5 and desc["nbytes"] == batch.nbytes
        try:
            got, _ = decode_partition(desc, copy=True)
            assert [it.checksum() for it in got] == \
                [it.checksum() for it in items]
            assert [it.labels for it in got] == [it.labels for it in items]
        finally:
            lease.release()

    def test_spill_file_roundtrip_and_magic(self, tmp_path):
        items = chunk_items(4)
        path = str(tmp_path / columnar_file_name(2, 7, "n0", "n1"))
        desc = write_columnar_file(path, ColumnarBatch.from_items(items))
        assert desc["columnar"] and desc["count"] == 4
        with open(path, "rb") as f:
            assert f.read(len(COLUMNAR_MAGIC)) == COLUMNAR_MAGIC
        got = read_partition_file(path, remove=True)
        assert [it.checksum() for it in got] == \
            [it.checksum() for it in items]
        assert not os.path.exists(path)        # consume-on-read

    def test_columnar_file_name_is_gc_visible(self):
        fn = columnar_file_name(3, 9, "n0", "n2")
        assert fn.startswith("columnar_") and is_exchange_file(fn)
        assert is_exchange_file(fn + ".tmp")   # torn temp half

    def test_encode_items_columnar_fast_path(self):
        items = chunk_items(6)
        batch = ColumnarBatch.from_items(items)
        for min_bytes in (1, 1 << 30):         # shm and inline routes
            payload, lease = encode_items(batch, shm_min_bytes=min_bytes)
            assert payload.get("columnar")
            try:
                got, glease = decode_items(payload)
                assert isinstance(got, ColumnarBatch)
                sums = [it.checksum() for it in got.to_items()]
                assert sums == [it.checksum() for it in items]
                del got                        # drop shm views pre-release
                if glease is not None:
                    glease.release()
            finally:
                if lease is not None:
                    lease.release()

    def test_partition_batch_order_and_bytes(self):
        items = [IngestItem({"x": np.arange(4, dtype=np.int64)},
                            Granularity.CHUNK)
                 .with_label("partition", i % 3).with_label("chunk", i)
                 for i in range(12)]
        targets = ["n0", "n1", "n2"]
        scalar = partition_items(items, "partition", targets)
        batch = partition_batch(ColumnarBatch.from_items(items),
                                "partition", targets)
        for t in targets:
            sc = scalar.get(t, [])
            assert batch[t].nbytes == sum(it.nbytes() for it in sc)
            assert [it.labels for it in batch[t].to_items()] == \
                [it.labels for it in sc]


# ---------------------------------------------------------------------------
class TestColumnarByteIdentityOracle:
    """Columnar off is the oracle: same shards, same plan, identical
    committed payload multiset — and columnar on keeps every
    zero-coordinator-bytes invariant."""

    @pytest.mark.parametrize("backend,mk", [
        ("thread", narrow_plan), ("thread", shuffled_plan),
        ("process", narrow_plan), ("process", shuffled_plan)])
    def test_columnar_matches_scalar_oracle(self, tmp_path, backend, mk):
        results, reports = {}, {}
        for col in (True, False):
            ds = DataStore(str(tmp_path / f"{mk.__name__}-{col}"),
                           nodes=NODES)
            eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                         queue_capacity=8, backend=backend,
                                         columnar=col)
            rep = eng.run_stream(mk(ds), shard_source(8))
            eng.close()
            assert read_rows(ds) == 8 * ROWS
            results[col], reports[col] = payload_hashes(ds), rep
        assert results[True] == results[False]
        rep = reports[True]
        assert rep.columnar_rounds() > 0
        assert rep.columnar_bytes() > 0
        assert rep.columnar_fallbacks() == 0
        assert reports[False].columnar_rounds() == 0
        for r in reports.values():             # invariants hold either way
            for e in r.epochs:
                assert e.run.shuffle_coordinator_bytes == 0
                assert e.run.stage_coordinator_bytes == 0
            # a pushed generator legitimately counts source bytes; the
            # all-three-zero invariant is asserted on the worker-pull
            # bench leg (bench_streaming --only streaming)


# ---------------------------------------------------------------------------
class TestColumnarFallback:
    def test_mixed_payloads_fall_back_per_producer(self, store):
        """A producer whose output won't pack deposits items the scalar
        way, flags the manifest, and the coordinator counts it."""
        eng = RuntimeEngine(store, backend="thread")
        try:
            rnd = ExchangeRound(xid=0, stage="a", key=None, epoch=-1,
                                targets=["n0"], consumers=["b"],
                                spill_share=1 << 20, columnar=True)
            mixed = [IngestItem(b"raw", Granularity.FILE),
                     IngestItem({"x": np.arange(3)}, Granularity.CHUNK)]
            res = eng._deposit_partitions(rnd, "n0", mixed)
            manifest = res["manifest"]
            assert manifest["columnar_fallback"] is True
            assert not manifest["parts"]["n0"].get("columnar")
            eng.shuffle.record_manifest(rnd, "n0", manifest)
            assert rnd.columnar_fallbacks == 1 and rnd.columnar_parts == 0
            got, _ = eng._exchange.collect(0, "n0")
            assert len(got) == 2
        finally:
            eng.close()

    def test_uniform_payloads_deposit_as_batch(self, store):
        eng = RuntimeEngine(store, backend="thread")
        try:
            rnd = ExchangeRound(xid=1, stage="a", key=None, epoch=-1,
                                targets=["n0"], consumers=["b"],
                                spill_share=1 << 20, columnar=True)
            items = chunk_items(4)
            res = eng._deposit_partitions(rnd, "n0", items)
            desc = res["manifest"]["parts"]["n0"]
            assert desc["columnar"] and desc["nbytes"] == \
                sum(it.nbytes() for it in items)
            eng.shuffle.record_manifest(rnd, "n0", res["manifest"])
            assert rnd.columnar_parts == 1 and rnd.columnar_fallbacks == 0
            got, _ = eng._exchange.collect(1, "n0")
            assert [it.checksum() for it in got] == \
                [it.checksum() for it in items]
        finally:
            eng.close()

    def test_columnar_spill_rides_columnar_file(self, store):
        """Past the spill share a columnar partition crosses as a
        ``columnar_*`` file and still collects through the magic sniff."""
        eng = RuntimeEngine(store, backend="thread")
        try:
            rnd = ExchangeRound(xid=2, stage="a", key=None, epoch=-1,
                                targets=["n0"], consumers=["b"],
                                spill_share=1, columnar=True)
            items = chunk_items(4)
            res = eng._deposit_partitions(rnd, "n0", items)
            desc = res["manifest"]["parts"]["n0"]
            assert desc["columnar"] and \
                os.path.basename(desc["spilled"]).startswith("columnar_")
            got, _ = eng._exchange.collect(2, "n0")
            assert [it.checksum() for it in got] == \
                [it.checksum() for it in items]
            assert not os.path.exists(desc["spilled"])  # consume-on-read
        finally:
            eng.close()


# ---------------------------------------------------------------------------
class TestColumnarDeathMatrix:
    """The PR-8 matrix with columnar edges enabled: a death mid-columnar-
    exchange must recover exactly-once with zero leaks — segment unlink
    and spill reclaim cover columnar descriptors like any other."""

    MATRIX = [(edge, fault, backend)
              for edge in ("narrow", "shuffle", "cross-segment")
              for fault in ("kill", "hang")
              for backend in ("thread", "process")]

    @pytest.mark.parametrize("edge,fault,backend", MATRIX)
    def test_death_matrix_columnar(self, tmp_path, edge, fault, backend):
        if backend == "thread" and fault == "hang":
            pytest.skip("thread executors cannot wedge independently of "
                        "the coordinator; hang renders as kill")
        before = shm_segments()
        ds = DataStore(str(tmp_path / f"{edge}-{fault}-{backend}"),
                       nodes=NODES)
        plan = shuffled_plan(ds) if edge == "shuffle" else narrow_plan(ds)
        hb = dict(heartbeat_interval_s=0.05, heartbeat_miss=3) \
            if (backend == "process" and fault == "hang") else {}
        eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend=backend,
                                     columnar=True, **hb)
        state = {}
        faults = None
        if backend == "thread":
            stage = {"narrow": "b", "shuffle": "b", "cross-segment": "c"}[edge]
            state["victim"] = "n2"
            faults = StreamFaultInjection(node_death_at={("n2", 1): stage})
        else:
            eng.prewarm_executors()
            stage = "b" if edge == "cross-segment" else "a"
            arm_signal(eng, fault, stage, state)
        rep = eng.run_stream(plan, shard_source(16, delay_s=0.01),
                             faults=faults)
        eng.close()
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        victim = state["victim"]
        assert victim and victim in rep.node_failures
        assert read_rows(ds) == 16 * ROWS      # exactly-once, always
        assert rep.columnar_rounds() > 0       # the plane was actually on
        assert rep.columnar_fallbacks() == 0
        if edge == "narrow" and backend == "thread":
            assert rep.cone_replays() >= 1
            assert 0 < rep.replayed_rows() < EPOCH_ROWS
        if edge == "shuffle":
            assert rep.cone_replays() == 0     # cone-incapable plan
        if backend == "process" and fault == "hang":
            assert [d for d in rep.liveness_deaths if d[0] == victim]
        assert_clean(ds, before)


# ---------------------------------------------------------------------------
class TestStreamChunking:
    """Satellite: a partition past ``STREAM_CHUNK_BYTES`` crosses as
    bounded chunk frames — never one oversized frame (spurious
    FrameError today)."""

    def test_oversized_partition_streams_in_chunks(self, tmp_path,
                                                   monkeypatch):
        from repro.core import transport
        monkeypatch.setattr(transport, "STREAM_CHUNK_BYTES", 1 << 10)
        blob = bytes(np.random.default_rng(0).integers(
            0, 256, 10_000, dtype=np.uint8))
        path = str(tmp_path / "big.part")
        with open(path, "wb") as f:
            f.write(blob)
        srv = PartitionStreamServer(str(tmp_path))
        try:
            got = fetch_stream_bytes(srv.endpoint, path)
            assert got == blob
            assert not os.path.exists(path)    # consume-on-read held
            assert srv.served == 1 and srv.served_bytes == len(blob)
        finally:
            srv.close()

    def test_exact_boundary_stays_single_frame(self, tmp_path, monkeypatch):
        from repro.core import transport
        monkeypatch.setattr(transport, "STREAM_CHUNK_BYTES", 1 << 10)
        blob = b"x" * (1 << 10)                # == chunk size: one frame
        path = str(tmp_path / "edge.part")
        with open(path, "wb") as f:
            f.write(blob)
        srv = PartitionStreamServer(str(tmp_path))
        try:
            assert fetch_stream_bytes(srv.endpoint, path) == blob
        finally:
            srv.close()

    def test_degraded_columnar_fetch_dispatches_on_magic(self, tmp_path,
                                                         monkeypatch):
        """End-to-end satellite pairing: an oversized COLUMNAR partition
        streams chunked and still decodes through the magic sniff."""
        from repro.core import transport
        from repro.core.exchange import fetch_stream_partition
        monkeypatch.setattr(transport, "STREAM_CHUNK_BYTES", 1 << 10)
        items = chunk_items(24, rows=64)       # payload well past 1 KiB
        path = str(tmp_path / columnar_file_name(0, 1, "n0", "n1"))
        write_columnar_file(path, ColumnarBatch.from_items(items))
        srv = PartitionStreamServer(str(tmp_path))
        try:
            got = fetch_stream_partition(
                {"path": path, "endpoint": list(srv.endpoint)})
            assert [it.checksum() for it in got] == \
                [it.checksum() for it in items]
        finally:
            srv.close()


# ---------------------------------------------------------------------------
class TestColumnarSpillGC:
    """Satellite: crashed ``columnar_*`` spills are crash garbage the
    store GC reclaims — exactly the PR-4/PR-5 resident/exchange story."""

    def test_gc_reclaims_crashed_columnar_spills(self, store):
        batch = ColumnarBatch.from_items(chunk_items(3))
        dead = os.path.join(store.dfs_dir, columnar_file_name(3, 7, "n0", "n1"))
        write_columnar_file(dead, batch)
        live = os.path.join(store.dfs_dir, columnar_file_name(4, 8, "n1", "n1"))
        write_columnar_file(live, batch)
        torn = os.path.join(store.dfs_dir,
                            columnar_file_name(5, 9, "n2", "n0") + ".tmp")
        with open(torn, "wb") as f:
            f.write(b"half-written")
        # a crash: a fresh DataStore on the same root holds no leases
        fresh = DataStore(store.root, nodes=store.nodes)
        fresh.lease_exchange_path(live)
        removed = fresh.gc_orphans()
        assert os.path.join("dfs", os.path.basename(dead)) in removed
        assert os.path.join("dfs", os.path.basename(torn)) in removed
        assert not os.path.exists(dead) and not os.path.exists(torn)
        assert os.path.exists(live)            # leased: spared
        fresh.release_exchange_path(live)
        assert os.path.join("dfs", os.path.basename(live)) in \
            fresh.gc_orphans()

    def test_crash_restart_end_to_end(self, tmp_path):
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
        batch = ColumnarBatch.from_items(chunk_items(2))
        for node in ("n0", "n1"):
            write_columnar_file(
                os.path.join(ds.dfs_dir,
                             columnar_file_name(2, 5, node, node)), batch)
        restarted = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
        removed = restarted.gc_orphans()
        assert len([r for r in removed if "columnar_" in r]) == 2
        assert not any(f.startswith("columnar_")
                       for f in os.listdir(restarted.dfs_dir))


# ---------------------------------------------------------------------------
class TestBulkRegistration:
    """The columnar data plane's store side (ISSUE 10): a whole upload
    batch registers under one lock in one coordinator round trip —
    identical entries and identical on-disk files to the per-block
    ``register_block_file`` protocol."""

    @staticmethod
    def _records(root, n):
        recs = []
        for i in range(n):
            node = f"n{i % 2}"
            tmp = os.path.join(root, "nodes", node, f".t{i}.tmp")
            os.makedirs(os.path.dirname(tmp), exist_ok=True)
            payload = bytes([i]) * (64 + i)
            with open(tmp, "wb") as f:
                f.write(payload)
            recs.append({"node": node, "tmp_path": tmp, "base": f"blk{i % 3}",
                         "checksum": f"c{i}", "nbytes": len(payload),
                         "raw_nbytes": len(payload), "compressed": False,
                         "labels": [["src", i]], "layout": "raw",
                         "logical_id": "", "replica_index": 0,
                         "stripe_id": "", "stripe_pos": -1,
                         "is_parity": False, "meta": {"i": i}, "epoch": -1})
        return recs

    def test_batch_matches_per_block_protocol(self, tmp_path):
        a = DataStore(str(tmp_path / "a"), nodes=["n0", "n1"])
        b = DataStore(str(tmp_path / "b"), nodes=["n0", "n1"])
        ra = self._records(a.root, 6)
        rb = self._records(b.root, 6)
        singles = [a.register_block_file(r.pop("node"), r.pop("tmp_path"),
                                         **r) for r in [dict(x) for x in ra]]
        batched = b.register_block_batch(rb)
        assert [e.block_id for e in batched] == [e.block_id for e in singles]
        for ea, eb in zip(singles, batched):
            assert ea == eb
        for e in batched:
            assert os.path.exists(os.path.join(b.root, e.path))
        assert not glob.glob(os.path.join(b.root, "nodes", "*", ".t*.tmp"))
        # id disambiguation matches: repeated bases got _1/_2 suffixes
        assert len({e.block_id for e in batched}) == 6

    def test_batch_rejects_committed_epoch_before_registering(self, store):
        store.begin_epoch(4)
        store.commit_epoch(4)
        recs = self._records(store.root, 3)
        recs[2]["epoch"] = 4
        with pytest.raises(ValueError, match="already committed"):
            store.register_block_batch(recs)
        # epoch validation runs batch-wide *before* any entry lands: the
        # failed batch registered nothing and renamed nothing
        assert not store.entries
        assert all(os.path.exists(r["tmp_path"]) for r in recs)


# ---------------------------------------------------------------------------
class TestPackKernelRoute:
    """``PackOp(use_pallas=True)`` routes the whole batch through
    ``kernels.pack_tokens`` — byte-identical to the scalar first-fit
    packer (the PR-7 erasure pattern)."""

    @staticmethod
    def _chunks(rng, n, seq_len_max=70):
        out = []
        for i in range(n):
            seqs = np.empty(int(rng.integers(1, 6)), object)
            for j in range(len(seqs)):
                seqs[j] = rng.integers(
                    0, 1000, int(rng.integers(1, seq_len_max))
                ).astype(np.int32)
            out.append(IngestItem({"tokens": seqs}, Granularity.CHUNK)
                       .with_label("chunk", i))
        return out

    def test_kernel_matches_scalar_oracle(self, rng):
        from repro.core.ops_format import PackOp
        items = self._chunks(rng, 5)
        scalar = PackOp(seq_len=32, rows_per_block=4).run_batch(
            copy.deepcopy(items))
        op = PackOp(seq_len=32, rows_per_block=4, use_pallas=True)
        kern = op.run_batch(copy.deepcopy(items))
        assert op._pack_kernel is not None
        assert len(scalar) == len(kern)
        for a, b in zip(scalar, kern):
            assert a.labels == b.labels and a.meta == b.meta
            for k in a.data:
                np.testing.assert_array_equal(a.data[k], b.data[k],
                                              err_msg=k)
        assert op.kernel_ms_total > 0

    def test_overlong_documents_split_identically(self, rng):
        from repro.core.ops_format import PackOp
        seqs = np.empty(1, object)
        seqs[0] = rng.integers(0, 9, 100).astype(np.int32)  # 100 > seq_len
        items = [IngestItem({"tokens": seqs}, Granularity.CHUNK)
                 .with_label("chunk", 0)]
        scalar = PackOp(seq_len=32).run_batch(copy.deepcopy(items))
        kern = PackOp(seq_len=32, use_pallas=True).run_batch(
            copy.deepcopy(items))
        for a, b in zip(scalar, kern):
            for k in a.data:
                np.testing.assert_array_equal(a.data[k], b.data[k])

    def test_kernel_failure_falls_back_to_scalar(self, rng):
        from repro.core.ops_format import PackOp
        op = PackOp(seq_len=32, rows_per_block=4, use_pallas=True)

        def boom(*a, **kw):
            raise RuntimeError("kernel down")
        op._pack_kernel = boom
        items = self._chunks(rng, 3)
        oracle = PackOp(seq_len=32, rows_per_block=4).run_batch(
            copy.deepcopy(items))
        out = op.run_batch(copy.deepcopy(items))
        assert len(out) == len(oracle)
        for a, b in zip(oracle, out):
            for k in a.data:
                np.testing.assert_array_equal(a.data[k], b.data[k])


# ---------------------------------------------------------------------------
class TestPerfGateColumnarMetric:
    def test_columnar_metric_is_gated_by_default(self, tmp_path):
        import json

        from benchmarks.perf_gate import DEFAULT_METRICS, main
        assert "columnar_rows_per_s" in DEFAULT_METRICS
        traj = str(tmp_path / "t.json")
        with open(traj, "w") as f:
            json.dump([
                {"scale": 1000, "pipelined_rows_per_s": 100.0,
                 "columnar_rows_per_s": 100.0},
                {"scale": 1000, "pipelined_rows_per_s": 100.0,
                 "columnar_rows_per_s": 50.0},
            ], f)
        assert main(["--file", traj]) == 1      # columnar regression gates
        with open(traj, "w") as f:
            json.dump([
                {"scale": 1000, "pipelined_rows_per_s": 100.0},
                {"scale": 1000, "pipelined_rows_per_s": 100.0,
                 "columnar_rows_per_s": 50.0},
            ], f)
        assert main(["--file", traj]) == 0      # pre-metric history skips
