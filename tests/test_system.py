"""End-to-end behaviour: the paper's log-analytics plan (Sec. IV-C) run on
the distributed runtime, with ingestion-aware access over the result."""
import numpy as np

from repro.core import (DataAccess, DataStore, IngestPlan, chain_stage,
                        create_stage, format_, ingest, select)
from repro.core import store as store_stmt
from repro.data.generators import as_file_items, gen_log_records


def build_log_plan(ds):
    """Fig. 2(a): 3 replicas; replicas 1-2 differ in layout (sorted row vs
    columnar), replica 3 is hash-partitioned + PAX-like."""
    p = IngestPlan("logs")
    s1 = select(p, replicate=2, replicate_tag="replicate1")
    s2 = select(p, s1, parser=None, replicate=2, replicate_tag="replicate2")
    s3 = format_(p, s2, chunk={"target_rows": 512})
    s4 = format_(p, s3, order={"key": "ts"}, serialize="sorted",
                 serialize_args={"key": "ts"})
    s5 = format_(p, s3, serialize="columnar")
    s6 = format_(p, s1, partition={"scheme": "hash", "key": "machine",
                                   "num_partitions": 4},
                 chunk={"target_rows": 512}, serialize="columnar")
    s7 = store_stmt(p, s4, s5, locate="disjoint")
    s8 = store_stmt(p, s6, locate="random")
    s9 = store_stmt(p, s7, s8, upload=ds)

    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2, s3], where={"replicate1": 1}, name="b")
    chain_stage(p, to=["a"], using=[s6, s8], where={"replicate1": 2}, name="c")
    chain_stage(p, to=["b"], using=[s4], where={"replicate2": 1}, name="d")
    chain_stage(p, to=["b"], using=[s5], where={"replicate2": 2}, name="e")
    chain_stage(p, to=["d", "e"], using=[s7], name="f")
    chain_stage(p, to=["c", "f"], using=[s9], name="g")
    return p


def test_log_analytics_end_to_end(tmp_path):
    ds = DataStore(str(tmp_path / "s"), nodes=[f"n{i}" for i in range(4)])
    n = 4000
    items = as_file_items(gen_log_records(n), shards=8)
    report = ingest(build_log_plan(ds), items, ds)

    assert not report.node_failures and not report.dummy_substitutions
    blocks = ds.blocks()
    assert blocks, "nothing stored"

    acc = DataAccess(ds)
    # replica 1: sorted rows -> index access on ts
    sorted_rows = acc.filter_replica("serialize", "sorted").read_all(
        projection=["ts"], selection=("ts", "<", 1000))
    assert (np.diff(sorted_rows["ts"]) >= 0).all()
    # replica 2: columnar
    col = acc.filter_replica("replicate2", 2).read_all(projection=["machine"])
    assert len(col["machine"]) == n
    # replica 3: hash partitioned — partition labels present and disjoint
    parts = acc.filter_replica("partition", None)
    by_part = {}
    for e in parts.entries:
        lab = dict((k, v) for k, v in e.labels)
        by_part.setdefault(lab.get("partition"), 0)
        by_part[lab.get("partition")] += 1
    assert len(by_part) == 4
    # lineage is encoded in physical file names (paper Sec. VII)
    assert any("serialize" in e.block_id for e in blocks)


def test_ingestion_aware_access_beats_naive_read(tmp_path):
    """Selection via the sorted layout reads fewer bytes than a full scan
    (the paper's Fig. 6(b) mechanism, asserted structurally)."""
    ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
    p = IngestPlan("t")
    s1 = select(p)
    s2 = format_(p, s1, chunk={"target_rows": 1024},
                 order={"key": "ts"}, serialize="sorted",
                 serialize_args={"key": "ts"})
    s3 = store_stmt(p, s2, upload=ds)
    create_stage(p, using=[s1, s2, s3])
    ingest(p, as_file_items(gen_log_records(8000), 4), ds)

    acc = DataAccess(ds).filter_replica("serialize", "sorted")
    rows = acc.read_all(projection=["ts", "machine"], selection=("ts", "<", 300))
    full = acc.read_all(projection=["ts"])
    assert len(rows["ts"]) < len(full["ts"])
    assert (rows["ts"] < 300).all()
