"""Continuous batching: per-slot positions + slot reuse, verified against
single-request decoding (the gold path)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.model import model_defs
from repro.models.params import init_params
from repro.serving import ContinuousBatcher, Request


def gold_continuation(cfg, params, prompt, n_new):
    """Reference: prefill+decode this request alone (uniform-pos path)."""
    import jax.numpy as jnp
    from repro.models.model import decode_step, prefill
    T = len(prompt)
    batch = {"tokens": jnp.asarray(prompt[None, :]),
             "segments": jnp.ones((1, T), jnp.int32),
             "positions": jnp.arange(T, dtype=jnp.int32)[None, :]}
    logits, cache = prefill(cfg, params, batch, max_len=128)
    out = [int(jnp.argmax(logits[0, -1]))]
    tok = jnp.asarray([[out[-1]]], jnp.int32)
    for i in range(n_new - 1):
        logits, cache = decode_step(cfg, params, cache, tok,
                                    jnp.asarray(T + i, jnp.int32))
        out.append(int(jnp.argmax(logits[0, -1])))
        tok = jnp.asarray([[out[-1]]], jnp.int32)
    return out


@pytest.mark.parametrize("arch", [
    "smollm-135m",
    pytest.param("glm4-9b", marks=pytest.mark.slow),  # ~15 s JAX compile
])
def test_matches_single_request_decoding(arch, rng):
    cfg = get_smoke(arch)
    params = init_params(jax.random.PRNGKey(0), model_defs(cfg))
    prompts = [rng.integers(1, cfg.vocab_size, rng.integers(8, 24)).astype(np.int32)
               for _ in range(5)]
    n_new = 6

    batcher = ContinuousBatcher(cfg, params, num_slots=2, max_len=128)
    for i, pr in enumerate(prompts):
        batcher.submit(Request(rid=i, prompt=pr, max_new_tokens=n_new))
    done = batcher.run()
    assert len(done) == 5
    assert all(len(r.generated) == n_new for r in done)

    for r in done:
        gold = gold_continuation(cfg, params, prompts[r.rid], n_new)
        assert r.generated == gold, (
            f"req {r.rid} (slot {r.slot}) diverged: {r.generated} vs {gold}")


def test_slots_are_reused(rng):
    cfg = get_smoke("smollm-135m")
    params = init_params(jax.random.PRNGKey(0), model_defs(cfg))
    batcher = ContinuousBatcher(cfg, params, num_slots=2, max_len=64)
    for i in range(6):
        batcher.submit(Request(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=3 + (i % 3)))
    done = batcher.run()
    assert len(done) == 6
    slots_used = {r.slot for r in done}
    assert slots_used == {0, 1}   # 6 requests through 2 slots
    # iteration-level scheduling: far fewer steps than serial decoding
    serial_steps = sum(3 + (i % 3) for i in range(6))
    assert batcher.steps < serial_steps
