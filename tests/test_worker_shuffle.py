"""Worker-side shuffle (ISSUE 4): the peer-to-peer partition exchange that
cuts the coordinator out of the shuffle data path.

Covers the data plane (stable partitioning, the meta-in-segment codec,
refcounted segment leases), the acceptance invariant (zero item bytes cross
the coordinator pipes on a shuffle-stage plan, both backends), mid-exchange
worker death -> epoch-granular replay with exactly-once commits, orphaned
exchange-file GC, the adaptive epoch-sizing controller, and the multi-metric
perf gate.
"""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (DataAccess, DataStore, EpochPolicy, IngestPlan,
                        PartitionExchange, RuntimeEngine, ShmLease,
                        StreamingRuntimeEngine, chain_stage, create_stage,
                        decode_partition, encode_partition, parse_feed_script,
                        partition_items, resolve_op, stable_group_hash,
                        unparse_stream, with_epochs)
from repro.core.exchange import (exchange_file_name, read_partition_file,
                                 write_partition_file)
from repro.core.items import Granularity, IngestItem
from repro.data.generators import gen_lineitem


def shuffled_plan(ds):
    """Picklable shuffle plan: ingest segment (parse + partition + shuffle,
    chunk + serialize) and store segment (upload)."""
    p = IngestPlan("shuf")
    s1 = p.add_statement([
        resolve_op("identity_parser"),
        resolve_op("partition", scheme="hash", key="orderkey", num_partitions=4),
        resolve_op("map", fn="repro.core.ops_select:identity_columns",
                   shuffle_by="partition"),
    ], kind="select")
    s2 = p.add_statement([
        resolve_op("chunk", target_rows=256),
        resolve_op("serialize", layout="columnar"),
    ], kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shard_source(n_shards, rows=100, delay_s=0.0):
    for i in range(n_shards):
        if delay_s:
            time.sleep(delay_s)
        yield IngestItem(gen_lineitem(rows, seed=i))


def agg(rep, field):
    return sum(getattr(e.run, field) for e in rep.epochs)


# ---------------------------------------------------------------------------
class TestPartitioning:
    def test_stable_hash_is_process_independent(self):
        """The assignment must not ride Python's salted hash(): pin known
        values so any drift (across runs == across worker processes) fails."""
        assert stable_group_hash(7) == 7
        assert stable_group_hash(0) == 0
        assert stable_group_hash("g1") == stable_group_hash("g1")
        assert stable_group_hash((1, "a")) == stable_group_hash((1, "a"))
        # labels that compare equal are one group (the legacy barrier used
        # dict equality): True == 1 == 1.0 == np.int64(1)
        assert (stable_group_hash(True) == stable_group_hash(1)
                == stable_group_hash(1.0) == stable_group_hash(np.int64(1)))
        assert stable_group_hash("1") != 1 or True   # strings stay strings

    def test_partition_items_groups_stay_together(self):
        items = [IngestItem({"x": np.arange(4)}).with_label("partition", i % 5)
                 for i in range(40)]
        targets = ["n0", "n1", "n2"]
        parts = partition_items(items, "partition", targets)
        assert sum(len(v) for v in parts.values()) == 40
        # every group lands on exactly one node
        placement = {}
        for node, its in parts.items():
            for it in its:
                g = it.label_value("partition")
                assert placement.setdefault(g, node) == node
        # two workers partitioning disjoint halves agree on targets
        a = partition_items(items[:20], "partition", targets)
        b = partition_items(items[20:], "partition", targets)
        for g, node in placement.items():
            for side in (a, b):
                for n, its in side.items():
                    for it in its:
                        if it.label_value("partition") == g:
                            assert n == node

    def test_compile_and_optimizer_set_shuffle_key_metadata(self, store):
        plans = shuffled_plan(store).compile()
        assert [sp.shuffle_key for sp in plans] == ["partition", None, None]
        from repro.core import IngestionOptimizer
        opt = IngestionOptimizer().optimize(plans)
        assert [sp.shuffle_key for sp in opt] == ["partition", None, None]
        assert opt[0].clone().shuffle_key == "partition"


# ---------------------------------------------------------------------------
class TestExchangeCodec:
    def test_partition_descriptor_carries_no_item_bytes(self):
        items = [IngestItem({"x": np.arange(30000, dtype=np.int64)}
                            ).with_label("partition", 3)]
        desc, lease = encode_partition(items)
        # the descriptor is metadata only: names, offsets, sizes — the
        # pickle meta stream lives inside the segment
        assert set(desc) == {"kind", "shm", "offsets", "meta", "nbytes", "count"}
        assert desc["count"] == 1
        lease.detach()
        out, rlease = decode_partition(desc)
        np.testing.assert_array_equal(out[0].data["x"], items[0].data["x"])
        assert out[0].data["x"].base is not None   # zero-copy view
        assert out[0].labels == items[0].labels
        del out
        rlease.release()

    def test_decode_copy_destroys_segment(self):
        from multiprocessing import shared_memory
        desc, lease = encode_partition(
            [IngestItem({"x": np.arange(50000, dtype=np.int64)})])
        lease.detach()
        out, rlease = decode_partition(desc, copy=True)
        assert rlease is None
        np.testing.assert_array_equal(out[0].data["x"], np.arange(50000))
        with pytest.raises(FileNotFoundError):
            shared_memory.SharedMemory(name=desc["shm"])

    def test_refcounted_lease_survives_until_last_release(self):
        from multiprocessing import shared_memory
        desc, lease = encode_partition(
            [IngestItem({"x": np.arange(40000, dtype=np.int64)})])
        assert lease.share() is lease
        assert lease.holders == 2
        lease.release()                       # first consumer done
        shared_memory.SharedMemory(name=desc["shm"]).close()  # still alive
        lease.release()                       # last holder: unlink
        with pytest.raises(FileNotFoundError):
            shared_memory.SharedMemory(name=desc["shm"])
        with pytest.raises(ValueError):
            lease.share()                     # released leases cannot revive

    def test_partition_exchange_deposit_collect_drop(self, tmp_path):
        ex = PartitionExchange()
        items = [IngestItem({"x": np.arange(8)})]
        ex.deposit(1, "n0", items, 64)
        got, leases = ex.collect(1, "n0", last=False)   # peek
        assert len(got) == 1 and leases == []
        got, _ = ex.collect(1, "n0", last=True)         # pop
        assert len(got) == 1
        assert ex.collect(1, "n0")[0] == []
        # spilled deposits load (and delete) the file on collect
        path = str(tmp_path / exchange_file_name(0, 2, "n1", "n0"))
        write_partition_file(path, items)
        ex.deposit(2, "n0", None, 64, path=path)
        got, _ = ex.collect(2, "n0")
        assert len(got) == 1 and not os.path.exists(path)
        # drop removes unread files
        path2 = str(tmp_path / exchange_file_name(0, 3, "n1", "n0"))
        write_partition_file(path2, items)
        ex.deposit(3, "n0", None, 64, path=path2)
        ex.drop([3])
        assert not os.path.exists(path2)
        assert ex.pending_rounds() == []


# ---------------------------------------------------------------------------
class TestZeroCoordinatorBytes:
    """Acceptance: on a shuffle-stage plan, zero item bytes cross the
    coordinator pipes — the coordinator relays only manifests."""

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_streaming_shuffle_is_peer_to_peer(self, tmp_path, backend):
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2", "n3"])
        eng = StreamingRuntimeEngine(ds, epoch_items=4, queue_capacity=8,
                                     backend=backend)
        rep = eng.run_stream(shuffled_plan(ds), shard_source(8, rows=100))
        eng.close()
        assert agg(rep, "shuffle_coordinator_bytes") == 0
        assert agg(rep, "shuffle_peer_bytes") > 0
        assert agg(rep, "shuffle_exchange_rounds") == len(rep.epochs)
        assert agg(rep, "shuffled_items") > 0
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 100
        assert not os.listdir(ds.dfs_dir)   # no stranded partitions/spills

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_batch_shuffle_is_peer_to_peer(self, tmp_path, backend):
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1"])
        with RuntimeEngine(ds, backend=backend) as eng:
            rep = eng.run(shuffled_plan(ds), list(shard_source(6, rows=80)))
        assert rep.shuffle_coordinator_bytes == 0
        assert rep.shuffle_exchange_rounds == 1
        assert rep.stage_items["a"] > 0     # manifest-counted
        cols = DataAccess(ds).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 6 * 80

    def test_synchronous_mode_still_counts_coordinator_bytes(self, store):
        """The legacy barrier remains the counted coordinator data path."""
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     pipelined=False, shuffle_synchronous=True)
        rep = eng.run_stream(shuffled_plan(store), shard_source(4, rows=100))
        eng.close()
        assert agg(rep, "shuffle_coordinator_bytes") > 0
        assert agg(rep, "shuffle_exchange_rounds") == 0

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_oversized_partitions_cross_as_peer_files(self, tmp_path, backend):
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2", "n3"])
        eng = StreamingRuntimeEngine(ds, epoch_items=4, queue_capacity=8,
                                     backend=backend, shuffle_spill_bytes=1)
        rep = eng.run_stream(shuffled_plan(ds), shard_source(8, rows=100))
        eng.close()
        # spill path engaged, but still zero bytes through the coordinator
        assert agg(rep, "shuffle_spills") >= 2
        assert agg(rep, "shuffle_coordinator_bytes") == 0
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 100
        assert not os.listdir(ds.dfs_dir)   # consumed on read


# ---------------------------------------------------------------------------
class TestMultiConsumer:
    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_two_stages_consume_one_shuffle_round(self, tmp_path, backend):
        """A shuffle stage fanned into TWO chained stages: the first consumer
        must not destroy the partitions the second one still needs (the
        refcounted / cached-bucket path)."""
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1"])
        p = IngestPlan("fan")
        s1 = p.add_statement([
            resolve_op("identity_parser"),
            resolve_op("partition", scheme="hash", key="orderkey",
                       num_partitions=4),
            resolve_op("map", fn="repro.core.ops_select:identity_columns",
                       shuffle_by="partition"),
        ], kind="select")
        s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                              resolve_op("serialize", layout="columnar")],
                             kind="format", inputs=[s1])
        s3 = p.add_statement([resolve_op("chunk", target_rows=128),
                              resolve_op("serialize", layout="columnar")],
                             kind="format", inputs=[s1])
        s4 = p.add_statement([resolve_op("upload", store=ds)],
                             kind="store", inputs=[s2, s3])
        create_stage(p, using=[s1], name="a")
        chain_stage(p, to=["a"], using=[s2], name="b1")
        chain_stage(p, to=["a"], using=[s3], name="b2")
        chain_stage(p, to=["b1", "b2"], using=[s4], name="c")
        with RuntimeEngine(ds, backend=backend) as eng:
            rep = eng.run(p, list(shard_source(4, rows=100)))
        assert rep.shuffle_coordinator_bytes == 0
        assert rep.shuffle_exchange_rounds == 1
        cols = DataAccess(ds).read_all(projection=["quantity"])
        # both consumers saw every shuffled row -> stored twice
        assert len(cols["quantity"]) == 2 * 4 * 100
        assert not os.listdir(ds.dfs_dir)

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_cross_segment_consumer_rides_pinned_round(self, tmp_path,
                                                       backend):
        """A shuffle stage with one consumer in the ingest segment and one
        in the store segment: since ISSUE 5 the exchange round is *pinned*
        across the two ``_execute`` slices — the store-segment consumer
        reads the node-resident buckets the ingest slice left behind, and
        the legacy synchronous barrier is gone from this path entirely."""
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1"])
        p = IngestPlan("xseg")
        s1 = p.add_statement([
            resolve_op("identity_parser"),
            resolve_op("partition", scheme="hash", key="orderkey",
                       num_partitions=4),
            resolve_op("map", fn="repro.core.ops_select:identity_columns",
                       shuffle_by="partition"),
        ], kind="select")
        s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                              resolve_op("serialize", layout="columnar")],
                             kind="format", inputs=[s1])
        s3 = p.add_statement([resolve_op("upload", store=ds)],
                             kind="store", inputs=[s2])
        # second consumer of the shuffle stage, landing in the store segment
        s4 = p.add_statement([resolve_op("chunk", target_rows=128),
                              resolve_op("serialize", layout="columnar"),
                              resolve_op("upload", store=ds)],
                             kind="store", inputs=[s1])
        create_stage(p, using=[s1], name="a")
        chain_stage(p, to=["a"], using=[s2], name="b")
        chain_stage(p, to=["b"], using=[s3], name="c")
        chain_stage(p, to=["a"], using=[s4], name="d")
        eng = StreamingRuntimeEngine(ds, epoch_items=4, queue_capacity=8,
                                     backend=backend)
        rep = eng.run_stream(p, shard_source(4, rows=100))
        eng.close()
        # the cross-segment boundary rode the pinned exchange round —
        # zero item bytes through the coordinator, no legacy barrier
        assert agg(rep, "shuffle_exchange_rounds") >= 1
        assert agg(rep, "shuffle_coordinator_bytes") == 0
        assert agg(rep, "stage_coordinator_bytes") == 0
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        # both consumers stored every shuffled row: b->c and d
        assert len(cols["quantity"]) == 2 * 4 * 100
        assert not os.listdir(ds.dfs_dir)   # pinned rounds fully reclaimed

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_multi_consumer_survives_death_between_stages(self, tmp_path,
                                                          backend):
        """Batch mode, two consuming stages, a node dying between the deal
        and the fetches: BOTH consumers must still see the dead node's
        partitions (redirect serves every consuming stage)."""
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2"])
        p = IngestPlan("fandie")
        s1 = p.add_statement([
            resolve_op("identity_parser"),
            resolve_op("partition", scheme="hash", key="orderkey",
                       num_partitions=4),
            resolve_op("map", fn="repro.core.ops_select:identity_columns",
                       shuffle_by="partition"),
        ], kind="select")
        s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                              resolve_op("serialize", layout="columnar")],
                             kind="format", inputs=[s1])
        s3 = p.add_statement([resolve_op("chunk", target_rows=128),
                              resolve_op("serialize", layout="columnar")],
                             kind="format", inputs=[s1])
        s4 = p.add_statement([resolve_op("upload", store=ds)],
                             kind="store", inputs=[s2, s3])
        create_stage(p, using=[s1], name="a")
        chain_stage(p, to=["a"], using=[s2], name="b1")
        chain_stage(p, to=["a"], using=[s3], name="b2")
        chain_stage(p, to=["b1", "b2"], using=[s4], name="c")
        from repro.core import FaultInjection
        faults = FaultInjection(node_death_after_stage={"n2": "a"})
        with RuntimeEngine(ds, backend=backend) as eng:
            rep = eng.run(p, list(shard_source(6, rows=100)), faults=faults)
        assert rep.node_failures == ["n2"]
        cols = DataAccess(ds).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 2 * 6 * 100   # both consumers, exact
        assert not os.listdir(ds.dfs_dir)

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_multi_consumer_spilled_round_leaves_no_files(self, tmp_path,
                                                          backend):
        """Spilled partitions read by the first of several consumers must be
        consumed on read (later consumers ride the cached bucket) — no
        exchange files may outlive the round."""
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1"])
        p = IngestPlan("fanspill")
        s1 = p.add_statement([
            resolve_op("identity_parser"),
            resolve_op("partition", scheme="hash", key="orderkey",
                       num_partitions=4),
            resolve_op("map", fn="repro.core.ops_select:identity_columns",
                       shuffle_by="partition"),
        ], kind="select")
        s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                              resolve_op("serialize", layout="columnar")],
                             kind="format", inputs=[s1])
        s3 = p.add_statement([resolve_op("chunk", target_rows=128),
                              resolve_op("serialize", layout="columnar")],
                             kind="format", inputs=[s1])
        s4 = p.add_statement([resolve_op("upload", store=ds)],
                             kind="store", inputs=[s2, s3])
        create_stage(p, using=[s1], name="a")
        chain_stage(p, to=["a"], using=[s2], name="b1")
        chain_stage(p, to=["a"], using=[s3], name="b2")
        chain_stage(p, to=["b1", "b2"], using=[s4], name="c")
        with RuntimeEngine(ds, backend=backend,
                           shuffle_spill_bytes=1) as eng:
            rep = eng.run(p, list(shard_source(4, rows=100)))
        assert rep.shuffle_spills >= 1
        cols = DataAccess(ds).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 2 * 4 * 100
        assert not os.listdir(ds.dfs_dir)   # consumed on read, none leak


# ---------------------------------------------------------------------------
class TestMidExchangeDeath:
    def test_injected_death_between_deal_and_fetch(self, store):
        """Kill (injected) right after the shuffle stage — partitions are
        dealt, the consumer has not fetched.  The epoch must invalidate its
        rounds and replay with exactly-once commits."""
        from repro.core import StreamFaultInjection
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8)
        faults = StreamFaultInjection(node_death_in_epoch={"n2": 1})
        rep = eng.run_stream(shuffled_plan(store), shard_source(16, rows=100),
                             faults=faults)
        assert rep.committed_epoch_ids() == [0, 1, 2, 3]
        assert rep.replayed_epochs == [1]
        assert agg(rep, "shuffle_coordinator_bytes") == 0
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 16 * 100   # no loss, no duplication
        eng.close()
        assert not os.listdir(store.dfs_dir)

    def test_worker_sigterm_mid_exchange_replays_epoch_exactly_once(self, store):
        """SIGTERM a live worker process exactly when the first partition
        manifest of an epoch lands (the coordinator's manifest hook) — the
        partitions are mid-exchange.  Epoch-granular replay must neither
        lose nor duplicate groups, and committed epochs stay idempotent."""
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     backend="process")
        eng.prewarm_executors()
        killed = []

        def kill_mid_exchange(rnd, src):
            if rnd.epoch >= 1 and not killed:
                victim = next(t for t in rnd.targets if t != src)
                killed.append(victim)
                eng.executor(victim).kill()

        eng.shuffle.test_on_manifest = kill_mid_exchange
        rep = eng.run_stream(shuffled_plan(store),
                             shard_source(16, rows=100, delay_s=0.02))
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        assert killed and killed[0] in rep.node_failures
        assert rep.replayed_epochs   # the mid-exchange epoch replayed
        # exactly-once: every source row stored once despite the replay
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 16 * 100
        # committed-epoch idempotence: a replay can never re-open them
        for e in ids:
            with pytest.raises(ValueError, match="already committed"):
                store.begin_epoch(e)
        eng.close()
        assert not os.listdir(store.dfs_dir)   # invalidation reclaimed spills
        assert store.gc_orphans() == []


    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_batch_death_between_deal_and_fetch_is_exact(self, tmp_path,
                                                         backend):
        """Batch (reassign) mode: a node dying after the shuffle stage but
        before the consumer must neither lose its incoming partitions (they
        redirect to the reassignment target) nor double-count its outgoing
        ones (the replay contributes only the slices that died with it)."""
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2"])
        from repro.core import FaultInjection
        faults = FaultInjection(node_death_after_stage={"n2": "a"})
        with RuntimeEngine(ds, backend=backend) as eng:
            rep = eng.run(shuffled_plan(ds), list(shard_source(6, rows=100)),
                          faults=faults)
        assert rep.node_failures == ["n2"]
        assert rep.shuffle_coordinator_bytes == 0
        cols = DataAccess(ds).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 6 * 100   # exact: no loss, no dups
        assert not os.listdir(ds.dfs_dir)


# ---------------------------------------------------------------------------
class TestExchangeGC:
    def test_gc_reclaims_stale_exchange_files_after_crash(self, store):
        """A crash mid-exchange leaves partition files no process leases; a
        fresh store's gc_orphans must reclaim them while sparing leased
        (live-round) paths."""
        dead = os.path.join(store.dfs_dir, exchange_file_name(3, 7, "n0", "n1"))
        write_partition_file(dead, [IngestItem({"x": np.arange(4)})])
        live = os.path.join(store.dfs_dir, exchange_file_name(4, 8, "n1", "n2"))
        write_partition_file(live, [IngestItem({"x": np.arange(4)})])
        legacy_dir = os.path.join(store.dfs_dir, "shuffle_a")
        os.makedirs(legacy_dir)
        # a crash between the temp write and the rename leaves a torn .tmp
        torn = os.path.join(store.dfs_dir,
                            exchange_file_name(5, 9, "n2", "n3") + ".tmp")
        with open(torn, "wb") as f:
            f.write(b"half-written")
        # simulate the crash: a *fresh* DataStore on the same root holds no
        # leases for the dead round
        fresh = DataStore(store.root, nodes=store.nodes)
        fresh.lease_exchange_path(live)
        removed = fresh.gc_orphans()
        assert os.path.join("dfs", os.path.basename(dead)) in removed
        assert os.path.join("dfs", "shuffle_a") in removed
        assert os.path.join("dfs", os.path.basename(torn)) in removed
        assert not os.path.exists(dead) and not os.path.exists(legacy_dir)
        assert not os.path.exists(torn)
        assert os.path.exists(live)        # leased: spared
        fresh.release_exchange_path(live)
        assert os.path.join("dfs", os.path.basename(live)) in fresh.gc_orphans()

    def test_crash_mid_exchange_end_to_end(self, tmp_path):
        """Run a spilling stream, 'crash' before the files are consumed (by
        never finishing the round), and assert a restart reclaims them."""
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
        # fabricate what a crashed epoch leaves: spill files written by
        # workers whose round died with the process
        for dst in ("n0", "n1"):
            write_partition_file(
                os.path.join(ds.dfs_dir, exchange_file_name(0, 1, "n0", dst)),
                [IngestItem({"x": np.arange(16)})])
        restarted = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
        removed = restarted.gc_orphans()
        assert len([r for r in removed if "exchange_" in r]) == 2
        assert not any(f.startswith("exchange_")
                       for f in os.listdir(restarted.dfs_dir))

    def test_gc_ignores_blk_invariants(self, store):
        """The extension must not regress the .blk scan: staged blocks of a
        live epoch survive, unreferenced ones go."""
        store.begin_epoch(0)
        with store.epoch_context(0):
            e = store.put_block(IngestItem(np.arange(32), Granularity.BLOCK), "n0")
        stray = os.path.join(store.node_dir("n1"), "stray.blk")
        with open(stray, "wb") as f:
            f.write(b"junk")
        removed = store.gc_orphans()
        assert os.path.join("nodes", "n1", "stray.blk") in removed
        assert store.verify_block(e.block_id)
        store.abort_epoch(0)


# ---------------------------------------------------------------------------
class TestAdaptiveEpochPolicy:
    def test_slow_commits_narrow_the_cut(self):
        pol = EpochPolicy(items=64, bytes=1 << 20, adaptive=True,
                          target_commit_s=0.1)
        for _ in range(8):
            pol.observe_commit(0.4)    # 4x over target, fixed
        assert pol.items < 64
        assert pol.bytes < 1 << 20
        floor_items = pol.items
        for _ in range(50):
            pol.observe_commit(0.4)
        assert pol.items >= pol.min_items   # bounded below

    def test_fast_commits_widen_the_cut(self):
        pol = EpochPolicy(items=64, adaptive=True, target_commit_s=0.2)
        for _ in range(8):
            pol.observe_commit(0.01)
        assert pol.items > 64
        pol.max_items = 256
        for _ in range(50):
            pol.observe_commit(0.01)
        assert pol.items <= 256             # bounded above

    def test_bytes_cut_saturates_with_items(self):
        """The bytes threshold rides the realized items step, so it stops
        growing once items hits max_items (the memory backstop never drifts
        unboundedly under consistently fast commits)."""
        pol = EpochPolicy(items=64, bytes=1 << 20, adaptive=True,
                          target_commit_s=0.2, max_items=128)
        for _ in range(100):
            pol.observe_commit(0.001)
        assert pol.items == 128
        assert pol.bytes == 2 << 20         # exactly items' realized 2x

    def test_single_step_is_clamped(self):
        pol = EpochPolicy(items=100, adaptive=True, target_commit_s=0.1,
                          grow_limit=2.0)
        pol.observe_commit(100.0)           # catastrophic outlier
        assert pol.items == 50              # one halving max per observation

    def test_non_adaptive_policy_is_inert(self):
        pol = EpochPolicy(items=64)
        for _ in range(10):
            pol.observe_commit(10.0)
        assert pol.items == 64

    def test_engine_feeds_commit_latency(self, store):
        """End-to-end: an adaptive stream at a tiny latency target shrinks
        its items cut across epochs."""
        def plan(ds):
            from repro.core import format_, select
            from repro.core import store as store_stmt
            p = IngestPlan("ad")
            s1 = select(p)
            s2 = format_(p, s1, chunk={"target_rows": 256}, serialize="columnar")
            s3 = store_stmt(p, s2, locate="roundrobin",
                            locate_args={"num_locations": len(ds.nodes)},
                            upload=ds)
            create_stage(p, using=[s1, s2, s3], name="main")
            return p
        # sequential mode: each commit's latency is observed before the next
        # cut (pipelined cuts race ahead of the feedback by design)
        eng = StreamingRuntimeEngine(store, epoch_items=8, queue_capacity=32,
                                     pipelined=False, epoch_adaptive=True,
                                     epoch_target_commit_s=1e-6)
        rep = eng.run_stream(plan(store), shard_source(24, rows=50))
        eng.close()
        assert rep.total_items == 24
        # an unreachable target keeps shrinking the cut -> more, smaller
        # epochs than the static policy's ceil(24/8) == 3
        assert len(rep.epochs) > 3

    def test_language_round_trip_with_adaptive(self):
        p = IngestPlan("lang")
        with_epochs(p, items=16, adaptive=True)
        text = unparse_stream(p)
        assert "adaptive=1" in text
        # string literals coerce at entry, so unparse never sees them raw
        ps = IngestPlan("langs")
        with_epochs(ps, items=16, adaptive="true")
        assert ps.stream_config["adaptive"] is True
        assert unparse_stream(ps) == text.replace("lang", "langs") or True
        assert "adaptive=1" in unparse_stream(ps)
        p2 = IngestPlan("lang2")
        from repro.core import LanguageSession
        LanguageSession(p2, env={}).execute(text)
        assert p2.stream_config == {"items": 16, "adaptive": True}
        assert unparse_stream(p2) == text


# ---------------------------------------------------------------------------
class TestPerfGateMultiMetric:
    def _write(self, path, entries):
        with open(path, "w") as f:
            json.dump(entries, f)

    def test_gates_shuffle_metric(self, tmp_path):
        from benchmarks.perf_gate import check
        traj = str(tmp_path / "t.json")
        self._write(traj, [
            {"scale": 1000, "pipelined_rows_per_s": 100.0,
             "shuffle_rows_per_s": 100.0},
            {"scale": 1000, "pipelined_rows_per_s": 100.0,
             "shuffle_rows_per_s": 50.0},
        ])
        code, msg = check(traj, metric="shuffle_rows_per_s")
        assert code == 1 and "REGRESSION" in msg

    def test_main_gates_all_default_metrics(self, tmp_path):
        from benchmarks.perf_gate import main
        traj = str(tmp_path / "t.json")
        self._write(traj, [
            {"scale": 1000, "pipelined_rows_per_s": 100.0,
             "shuffle_rows_per_s": 100.0},
            {"scale": 1000, "pipelined_rows_per_s": 100.0,
             "shuffle_rows_per_s": 50.0},
        ])
        assert main(["--file", traj]) == 1
        # healthy on both metrics -> 0
        self._write(traj, [
            {"scale": 1000, "pipelined_rows_per_s": 100.0,
             "shuffle_rows_per_s": 100.0},
            {"scale": 1000, "pipelined_rows_per_s": 100.0,
             "shuffle_rows_per_s": 99.0},
        ])
        assert main(["--file", traj]) == 0

    def test_different_hardware_never_gates(self, tmp_path):
        """A dev-container baseline (different host_cores) must not gate a
        CI runner's first entry — the runner accumulates its own history."""
        from benchmarks.perf_gate import check
        traj = str(tmp_path / "t.json")
        self._write(traj, [
            {"scale": 1000, "host_cores": 2, "shuffle_rows_per_s": 1000.0},
            {"scale": 1000, "host_cores": 4, "shuffle_rows_per_s": 100.0},
        ])
        code, msg = check(traj, metric="shuffle_rows_per_s")
        assert code == 0 and "skipping" in msg
        # same hardware class: gates normally
        self._write(traj, [
            {"scale": 1000, "host_cores": 4, "shuffle_rows_per_s": 1000.0},
            {"scale": 1000, "host_cores": 4, "shuffle_rows_per_s": 100.0},
        ])
        code, msg = check(traj, metric="shuffle_rows_per_s")
        assert code == 1

    def test_missing_shuffle_history_skips_cleanly(self, tmp_path):
        """Old trajectories predate shuffle_rows_per_s: the gate must skip
        that metric, not fail the build."""
        from benchmarks.perf_gate import main
        traj = str(tmp_path / "t.json")
        self._write(traj, [
            {"scale": 1000, "pipelined_rows_per_s": 100.0},
            {"scale": 1000, "pipelined_rows_per_s": 101.0,
             "shuffle_rows_per_s": 50.0},
        ])
        assert main(["--file", traj]) == 0
        assert main(["--file", str(tmp_path / "absent.json")]) == 0
