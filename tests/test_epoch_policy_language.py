"""ISSUE 3 satellites: byte-based epoch cuts (EpochPolicy), spill-aware
shuffle sizing from a memory budget, manifest-journal auto-compaction, the
language round-trip (STREAM WITH EPOCHS parse -> unparse -> parse, FEED
error paths), and the nightly perf gate."""
import json
import os

import numpy as np
import pytest

from repro.core import (DataStore, EpochPolicy, IngestPlan, IngestQueues,
                        StreamingRuntimeEngine, create_stage, derive_spill_bytes,
                        format_, parse_feed_script, parse_ingestion_script,
                        select, unparse_stream, with_epochs)
from repro.core import store as store_stmt
from repro.core.items import Granularity, IngestItem
from repro.core.language import LanguageError
from repro.core.runtime import MIN_SPILL_BYTES
from repro.data.generators import gen_lineitem


def columnar_plan(ds, **epoch_kw):
    p = IngestPlan("pol")
    s1 = select(p)
    s2 = format_(p, s1, chunk={"target_rows": 256}, serialize="columnar")
    s3 = store_stmt(p, s2, locate="roundrobin",
                    locate_args={"num_locations": len(ds.nodes)}, upload=ds)
    create_stage(p, using=[s1, s2, s3], name="main")
    if epoch_kw:
        with_epochs(p, **epoch_kw)
    return p


def shard_source(n_shards, rows=100):
    for i in range(n_shards):
        yield IngestItem(gen_lineitem(rows, seed=i))


# ---------------------------------------------------------------------------
class TestEpochPolicy:
    def test_bytes_threshold_cuts_epochs(self, store):
        """With a byte cut far below the item budget, epochs close early —
        more, smaller epochs than the item policy alone would give."""
        rows = 200
        item_bytes = IngestItem(gen_lineitem(rows, seed=0)).nbytes()
        eng = StreamingRuntimeEngine(store, epoch_items=100,
                                     epoch_bytes=2 * item_bytes,
                                     queue_capacity=16)
        rep = eng.run_stream(columnar_plan(store), shard_source(8, rows=rows))
        eng.close()
        assert len(rep.epochs) >= 4          # ~2 items per epoch, 8 items
        assert rep.total_items == 8

    def test_policy_resolves_plan_config_over_engine_defaults(self, store):
        eng = StreamingRuntimeEngine(store, epoch_items=7)
        pol = eng._config(columnar_plan(store, items=3, bytes=1 << 20,
                                        capacity=9))
        assert pol == EpochPolicy(items=3, seconds=None, bytes=1 << 20,
                                  capacity=9)
        # no plan config: engine defaults
        assert eng._config(columnar_plan(store)).items == 7
        eng.close()

    def test_cut_epoch_by_bytes_direct(self):
        items = [IngestItem({"x": np.zeros(1000, dtype=np.int64)})
                 for _ in range(6)]
        q = IngestQueues(iter(items), ["n0"], capacity=16)
        q.exhausted.wait(timeout=5)
        batch = q.cut_epoch(100, max_bytes=2 * 8000)
        assert sum(len(v) for v in batch.values()) == 2
        q.stop()

    def test_stream_with_epochs_bytes_knob(self):
        plan = parse_ingestion_script(
            "SELECT * FROM input; STREAM WITH EPOCHS(items=16, bytes=4mb);")
        assert plan.stream_config == {"items": 16, "bytes": 4 << 20}


# ---------------------------------------------------------------------------
class TestLanguageRoundTrip:
    def test_stream_parse_unparse_parse_stable(self):
        script = "SELECT * FROM input; STREAM WITH EPOCHS(items=128, seconds=0.5, bytes=1048576, capacity=64);"
        p1 = parse_ingestion_script(script)
        text = unparse_stream(p1)
        p2 = parse_ingestion_script("SELECT * FROM input; " + text)
        assert p2.stream_config == p1.stream_config
        assert unparse_stream(p2) == text

    def test_unparse_without_stream_config_raises(self):
        with pytest.raises(LanguageError, match="no stream config"):
            unparse_stream(IngestPlan("bare"))

    def test_feed_unknown_plan_rejected(self):
        p = IngestPlan("known")
        with pytest.raises(LanguageError, match="missing"):
            parse_feed_script("FEED input INTO missing;", env={"known": p})

    def test_feed_empty_target_list_rejected(self):
        for script in ("FEED input INTO ;", "FEED input;", "FEED input INTO ,,;"):
            with pytest.raises(LanguageError):
                parse_feed_script(script, env={})

    def test_script_without_feed_rejected(self):
        with pytest.raises(LanguageError, match="no FEED"):
            parse_feed_script("SELECT * FROM input;", env={})


# ---------------------------------------------------------------------------
class TestSpillAwareShuffleSizing:
    def test_derive_spill_bytes_math(self):
        assert derive_spill_bytes(64 << 20, 16 << 20) == 48 << 20
        # floor: a tiny budget never forces every round to the DFS
        assert derive_spill_bytes(1 << 20, 10 << 20) == MIN_SPILL_BYTES

    def test_engine_derives_spill_from_budget(self, store):
        eng = StreamingRuntimeEngine(store, memory_budget_bytes=64 << 20)
        assert eng.shuffle.spill_bytes == derive_spill_bytes(64 << 20)
        eng.close()

    def test_explicit_spill_bytes_wins_over_budget(self, store):
        eng = StreamingRuntimeEngine(store, memory_budget_bytes=64 << 20,
                                     shuffle_spill_bytes=123456)
        assert eng.shuffle.spill_bytes == 123456
        q = IngestQueues.manual(store.nodes, capacity=4)
        eng._update_spill_budget(q)
        assert eng.shuffle.spill_bytes == 123456   # still pinned
        eng.close()

    def test_budget_adapts_to_observed_item_bytes(self, store):
        eng = StreamingRuntimeEngine(store, memory_budget_bytes=64 << 20,
                                     queue_capacity=4)
        q = IngestQueues.manual(store.nodes, capacity=4)
        big = IngestItem({"x": np.zeros(1 << 18, dtype=np.int64)})  # 2 MiB
        q.put(big)
        eng._update_spill_budget(q)
        reserved = 4 * len(store.nodes) * q.avg_item_bytes()
        assert eng.shuffle.spill_bytes == derive_spill_bytes(64 << 20, reserved)
        assert eng.shuffle.spill_bytes < derive_spill_bytes(64 << 20)
        q.stop()
        eng.close()


# ---------------------------------------------------------------------------
class TestJournalAutoCompaction:
    def _commit(self, ds, epoch):
        ds.begin_epoch(epoch)
        ds.put_block(IngestItem(np.arange(8), Granularity.BLOCK),
                     ds.nodes[0])
        ds.commit_epoch(epoch)

    def test_journal_folds_into_snapshot_past_threshold(self, tmp_path):
        ds = DataStore(str(tmp_path / "s"), nodes=["n0"],
                       journal_compact_lines=3)
        for e in range(6):
            self._commit(ds, e)
        # after compaction the journal holds at most the threshold's worth
        lines = 0
        if os.path.exists(ds.epoch_journal_path):
            with open(ds.epoch_journal_path) as f:
                lines = len(f.readlines())
        assert lines <= 3
        # a fresh open replays snapshot + short journal: nothing lost
        revived = DataStore(ds.root, nodes=["n0"], journal_compact_lines=3)
        assert revived.committed_epoch_ids() == list(range(6))

    def test_compaction_disabled_with_zero(self, tmp_path):
        ds = DataStore(str(tmp_path / "s"), nodes=["n0"],
                       journal_compact_lines=0)
        for e in range(5):
            self._commit(ds, e)
        with open(ds.epoch_journal_path) as f:
            assert len(f.readlines()) == 5   # untouched journal


# ---------------------------------------------------------------------------
class TestPerfGate:
    def _write(self, path, entries):
        with open(path, "w") as f:
            json.dump(entries, f)

    def test_missing_and_short_history_skip(self, tmp_path):
        from benchmarks.perf_gate import check
        code, msg = check(str(tmp_path / "nope.json"))
        assert code == 0 and "skip" in msg
        p = str(tmp_path / "one.json")
        self._write(p, [{"pipelined_rows_per_s": 1000.0}])
        code, msg = check(p)
        assert code == 0 and "nothing to compare" in msg

    def test_regression_fails(self, tmp_path):
        from benchmarks.perf_gate import check
        p = str(tmp_path / "t.json")
        self._write(p, [{"pipelined_rows_per_s": 1000.0},
                        {"pipelined_rows_per_s": 700.0}])
        code, msg = check(p, threshold=0.25)
        assert code == 1 and "REGRESSION" in msg

    def test_within_budget_and_improvement_pass(self, tmp_path):
        from benchmarks.perf_gate import check
        p = str(tmp_path / "t.json")
        self._write(p, [{"pipelined_rows_per_s": 1000.0},
                        {"pipelined_rows_per_s": 800.0}])
        assert check(p, threshold=0.25)[0] == 0
        self._write(p, [{"pipelined_rows_per_s": 1000.0},
                        {"pipelined_rows_per_s": 1400.0}])
        assert check(p, threshold=0.25)[0] == 0

    def test_baseline_must_match_scale(self, tmp_path):
        """A manual run at another scale is not a comparable baseline."""
        from benchmarks.perf_gate import check
        p = str(tmp_path / "t.json")
        # last entry at scale 200k: the 50k entry in between is ignored,
        # so the real 200k baseline gates the comparison
        self._write(p, [{"scale": 200000, "pipelined_rows_per_s": 1000.0},
                        {"scale": 50000, "pipelined_rows_per_s": 100.0},
                        {"scale": 200000, "pipelined_rows_per_s": 700.0}])
        code, msg = check(p, threshold=0.25)
        assert code == 1 and "REGRESSION" in msg
        # only cross-scale history: nothing comparable, clean skip
        self._write(p, [{"scale": 50000, "pipelined_rows_per_s": 100.0},
                        {"scale": 200000, "pipelined_rows_per_s": 700.0}])
        code, msg = check(p, threshold=0.25)
        assert code == 0 and "nothing to compare" in msg

    def test_unreadable_trajectory_skips(self, tmp_path):
        from repro.core import DataStore  # noqa: F401 (import side effects none)
        from benchmarks.perf_gate import check
        p = str(tmp_path / "bad.json")
        with open(p, "w") as f:
            f.write("{not json")
        code, msg = check(p)
        assert code == 0 and "skip" in msg
