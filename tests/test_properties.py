"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.erasure import ReedSolomon
from repro.erasure.gf256 import GF256

FAST = settings(max_examples=25, deadline=None,
                suppress_health_check=[HealthCheck.function_scoped_fixture])


# ------------------------------------------------------------------ GF(2^8)
class TestGF256:
    @FAST
    @given(st.integers(1, 255), st.integers(1, 255), st.integers(1, 255))
    def test_field_axioms(self, a, b, c):
        A, B, C = (np.array([x], np.uint8) for x in (a, b, c))
        assert GF256.mul(A, B) == GF256.mul(B, A)
        assert GF256.mul(A, GF256.mul(B, C)) == GF256.mul(GF256.mul(A, B), C)
        # distributivity over xor
        assert GF256.mul(A, B ^ C) == (GF256.mul(A, B) ^ GF256.mul(A, C))

    @FAST
    @given(st.integers(1, 255))
    def test_inverse(self, a):
        A = np.array([a], np.uint8)
        inv = GF256.inv(A)
        assert GF256.mul(A, inv) == np.array([1], np.uint8)

    @FAST
    @given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 2**31 - 1))
    def test_matrix_inverse_roundtrip(self, k, m, seed):
        rng = np.random.default_rng(seed)
        rs = ReedSolomon(k, m)
        G = np.concatenate([np.eye(k, dtype=np.uint8), rs.C], axis=0)
        rows = rng.permutation(k + m)[:k]
        A = G[sorted(rows)]
        A_inv = GF256.mat_inv(A)
        assert (GF256.matmul(A_inv, A) == np.eye(k, dtype=np.uint8)).all()


# -------------------------------------------------------------- Reed-Solomon
class TestReedSolomon:
    @FAST
    @given(st.integers(2, 8), st.integers(1, 4), st.integers(0, 2**31 - 1),
           st.integers(16, 400))
    def test_survives_any_m_erasures(self, k, m, seed, L):
        """THE erasure-coding invariant: any <= m lost rows are recoverable."""
        rng = np.random.default_rng(seed)
        rs = ReedSolomon(k, m)
        data = rng.integers(0, 256, (k, L)).astype(np.uint8)
        parity = rs.encode(data)
        full = np.concatenate([data, parity], axis=0)
        lost = rng.permutation(k + m)[:m]
        shards = {i: full[i] for i in range(k + m) if i not in set(lost)}
        for pos in lost:
            rec = rs.recover_block(int(pos), dict(shards))
            assert (rec == full[pos]).all(), f"row {pos} mismatch"

    @FAST
    @given(st.integers(0, 2**31 - 1))
    def test_kernel_path_matches_numpy_path(self, seed):
        rng = np.random.default_rng(seed)
        rs_np = ReedSolomon(4, 2, use_pallas=False)
        rs_pl = ReedSolomon(4, 2, use_pallas=True)
        data = rng.integers(0, 256, (4, 256)).astype(np.uint8)
        assert (rs_np.encode(data) == rs_pl.encode(data)).all()


# ------------------------------------------------------------------- packing
class TestPackingConservation:
    @FAST
    @given(st.lists(st.integers(1, 300), min_size=1, max_size=40),
           st.integers(64, 256), st.integers(0, 2**31 - 1))
    def test_packing_conserves_tokens(self, doc_lens, seq_len, seed):
        """No token is lost or duplicated by the packer (docs longer than
        seq_len are split, not dropped)."""
        from repro.core.ops_format import PackOp
        from repro.core.items import Granularity, IngestItem

        rng = np.random.default_rng(seed)
        docs = [rng.integers(1, 1000, L).astype(np.int32) for L in doc_lens]
        cols = {"tokens": np.array(docs, dtype=object),
                "length": np.array(doc_lens, np.int32)}
        op = PackOp(seq_len=seq_len, rows_per_block=4, pad_id=0)
        outs = op.run([IngestItem(cols, Granularity.CHUNK)])
        total_in = sum(doc_lens)
        total_out = 0
        for it in outs:
            blk = it.data
            cols_out = blk if isinstance(blk, dict) else None
            assert cols_out is not None
            mask = cols_out["segment_ids"] > 0
            total_out += int(mask.sum())
            # positions restart within each segment
            toks = cols_out["tokens"]
            assert toks.shape[1] == seq_len
        assert total_out == total_in

    @FAST
    @given(st.lists(st.integers(1, 200), min_size=2, max_size=30),
           st.integers(0, 2**31 - 1))
    def test_packed_segments_do_not_interleave(self, doc_lens, seed):
        from repro.core.ops_format import PackOp
        from repro.core.items import Granularity, IngestItem

        rng = np.random.default_rng(seed)
        docs = [rng.integers(1, 1000, L).astype(np.int32) for L in doc_lens]
        cols = {"tokens": np.array(docs, dtype=object),
                "length": np.array(doc_lens, np.int32)}
        op = PackOp(seq_len=128, rows_per_block=4, pad_id=0)
        for it in op.run([IngestItem(cols, Granularity.CHUNK)]):
            seg = it.data["segment_ids"]
            for row in seg:
                nz = row[row > 0]
                # segment ids are non-decreasing within a row (contiguous runs)
                assert (np.diff(nz) >= 0).all()


# ----------------------------------------------------------- access invariants
class TestAccessInvariants:
    @FAST
    @given(st.integers(2, 6), st.integers(0, 2**31 - 1))
    def test_split_by_key_is_a_partition(self, num_tasks, seed):
        import tempfile
        from repro.core import DataAccess, DataStore, IngestPlan, create_stage, format_, ingest, select
        from repro.core import store as store_stmt
        from repro.data.generators import as_file_items, gen_lineitem

        tmp = tempfile.mkdtemp()
        ds = DataStore(tmp, nodes=["n0", "n1"])
        p = IngestPlan("t")
        s1 = select(p)
        s2 = format_(p, s1, partition={"scheme": "hash", "key": "suppkey",
                                       "num_partitions": 5},
                     chunk={"target_rows": 128}, serialize="columnar")
        s3 = store_stmt(p, s2, upload=ds)
        create_stage(p, using=[s1, s2, s3])
        ingest(p, as_file_items(gen_lineitem(600, seed=seed % 1000), 2), ds)

        acc = DataAccess(ds)
        splits = acc.split_by_key("partition", num_tasks=num_tasks)
        ids = [e.block_id for s in splits for e in s.blocks]
        assert len(ids) == len(set(ids))            # disjoint
        assert set(ids) == {e.block_id for e in acc.entries}  # exhaustive


# -------------------------------------------------------- label round-trips
class TestLineage:
    @FAST
    @given(st.lists(st.tuples(st.sampled_from(["parser", "replicate", "chunk",
                                               "serialize", "locate"]),
                              st.integers(0, 99)), min_size=1, max_size=8))
    def test_lineage_name_preserves_order(self, labels):
        from repro.core.items import Granularity, IngestItem
        it = IngestItem(b"x", Granularity.FILE)
        for op, v in labels:
            it = it.with_label(op, v)
        name = it.lineage_name()
        parts = name.split("_")
        assert len(parts) == len(labels)
        for (op, v), part in zip(labels, parts):
            assert part.startswith(op)


# ------------------------------------------------- columnar batch (ISSUE 10)
class TestColumnarBatchRoundTrip:
    """``ColumnarBatch.from_items -> to_items`` must be the identity on
    every batch it accepts — including empty batches, zero-length payloads,
    non-ASCII label/metadata strings, and payload buffers viewed at
    unaligned offsets (the shm-segment case)."""

    @staticmethod
    def _assert_items_equal(a, b):
        from repro.layouts.blocks import SerializedBlock
        assert len(a) == len(b)
        for x, y in zip(a, b):
            assert x.granularity == y.granularity
            assert x.labels == y.labels
            assert x.meta == y.meta
            if isinstance(x.data, np.ndarray):
                assert y.data.dtype == x.data.dtype
                assert y.data.shape == x.data.shape
                assert (y.data == x.data).all()
            elif isinstance(x.data, dict):
                assert tuple(y.data.keys()) == tuple(x.data.keys())
                for k in x.data:
                    assert y.data[k].dtype == x.data[k].dtype
                    assert (y.data[k] == x.data[k]).all()
            elif isinstance(x.data, SerializedBlock):
                assert y.data.layout == x.data.layout
                assert y.data.header == x.data.header
                assert bytes(y.data.payload) == bytes(x.data.payload)
            else:
                assert y.data == x.data

    @staticmethod
    def _roundtrip(items):
        from repro.core.items import ColumnarBatch
        batch = ColumnarBatch.from_items(items)
        assert batch is not None
        assert batch.nbytes == sum(it.nbytes() for it in items)
        return batch

    def test_empty_batch(self):
        from repro.core.items import ColumnarBatch
        batch = ColumnarBatch.from_items([])
        assert batch is not None and len(batch) == 0
        assert batch.nbytes == 0 and batch.to_items() == []

    @FAST
    @given(st.lists(st.binary(max_size=48), min_size=1, max_size=8),
           st.text(min_size=0, max_size=8))
    def test_bytes_roundtrip(self, blobs, tag):
        """Raw byte payloads — including b"" — and arbitrary (non-ASCII)
        label strings survive the column pack."""
        from repro.core.items import Granularity, IngestItem
        items = [IngestItem(b, Granularity.FILE,
                            meta={"tag": tag} if tag else {})
                 .with_label("parser", tag) for b in blobs]
        batch = self._roundtrip(items)
        self._assert_items_equal(items, batch.to_items())

    @FAST
    @given(st.lists(st.integers(0, 12), min_size=1, max_size=6),
           st.sampled_from(["<i8", "<f4", "<u2"]),
           st.integers(0, 2**31 - 1))
    def test_array_roundtrip(self, lens, dtype, seed):
        """Same-dtype ndarray payloads, zero-length arrays included."""
        from repro.core.items import Granularity, IngestItem
        rng = np.random.default_rng(seed)
        items = [IngestItem((rng.integers(0, 100, n)).astype(dtype),
                            Granularity.BLOCK).with_label("locate", i)
                 for i, n in enumerate(lens)]
        batch = self._roundtrip(items)
        self._assert_items_equal(items, batch.to_items())

    @FAST
    @given(st.lists(st.integers(0, 9), min_size=1, max_size=5),
           st.lists(st.text(min_size=1, max_size=6), min_size=1, max_size=4,
                    unique=True),
           st.integers(0, 2**31 - 1))
    def test_columns_roundtrip(self, rows, keys, seed):
        """Dict-of-arrays chunks sharing a schema (row offsets), with
        non-ASCII field names."""
        from repro.core.items import Granularity, IngestItem
        rng = np.random.default_rng(seed)
        items = [IngestItem({k: rng.integers(0, 50, r).astype(np.int64)
                             for k in keys}, Granularity.CHUNK)
                 .with_label("chunk", i) for i, r in enumerate(rows)]
        batch = self._roundtrip(items)
        self._assert_items_equal(items, batch.to_items())

    @FAST
    @given(st.lists(st.binary(max_size=32), min_size=1, max_size=6),
           st.integers(1, 7))
    def test_header_roundtrip_from_unaligned_view(self, blobs, pad):
        """``header()/from_header`` round-trip with the payload living at an
        arbitrary (unaligned) offset inside a larger buffer — exactly how a
        decoded shm segment hands the batch its bytes."""
        from repro.core.items import ColumnarBatch, Granularity, IngestItem
        items = [IngestItem(b, Granularity.FILE).with_label("parser", i)
                 for i, b in enumerate(blobs)]
        batch = self._roundtrip(items)
        buf = np.zeros(pad + batch.nbytes, np.uint8)
        buf[pad:] = batch.payload
        back = ColumnarBatch.from_header(batch.header(), buf[pad:])
        self._assert_items_equal(items, back.to_items())

    @FAST
    @given(st.lists(st.integers(1, 8), min_size=2, max_size=6),
           st.integers(2, 4), st.integers(0, 2**31 - 1))
    def test_partition_batch_matches_scalar(self, rows, n_targets, seed):
        """``partition_batch`` over the packed batch must equal
        ``partition_items`` over the item list — same membership, same
        order, same per-partition bytes."""
        from repro.core.exchange import partition_batch, partition_items
        from repro.core.items import ColumnarBatch, Granularity, IngestItem
        rng = np.random.default_rng(seed)
        items = [IngestItem({"x": rng.integers(0, 50, r).astype(np.int64)},
                            Granularity.CHUNK)
                 .with_label("partition", int(rng.integers(0, 100)))
                 for r in rows]
        targets = [f"n{i}" for i in range(n_targets)]
        scalar = partition_items(items, "partition", targets)
        batch = ColumnarBatch.from_items(items)
        assert batch is not None
        cols = partition_batch(batch, "partition", targets)
        for t in targets:
            self._assert_items_equal(scalar.get(t, []),
                                     cols[t].to_items())
