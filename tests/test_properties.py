"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.erasure import ReedSolomon
from repro.erasure.gf256 import GF256

FAST = settings(max_examples=25, deadline=None,
                suppress_health_check=[HealthCheck.function_scoped_fixture])


# ------------------------------------------------------------------ GF(2^8)
class TestGF256:
    @FAST
    @given(st.integers(1, 255), st.integers(1, 255), st.integers(1, 255))
    def test_field_axioms(self, a, b, c):
        A, B, C = (np.array([x], np.uint8) for x in (a, b, c))
        assert GF256.mul(A, B) == GF256.mul(B, A)
        assert GF256.mul(A, GF256.mul(B, C)) == GF256.mul(GF256.mul(A, B), C)
        # distributivity over xor
        assert GF256.mul(A, B ^ C) == (GF256.mul(A, B) ^ GF256.mul(A, C))

    @FAST
    @given(st.integers(1, 255))
    def test_inverse(self, a):
        A = np.array([a], np.uint8)
        inv = GF256.inv(A)
        assert GF256.mul(A, inv) == np.array([1], np.uint8)

    @FAST
    @given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 2**31 - 1))
    def test_matrix_inverse_roundtrip(self, k, m, seed):
        rng = np.random.default_rng(seed)
        rs = ReedSolomon(k, m)
        G = np.concatenate([np.eye(k, dtype=np.uint8), rs.C], axis=0)
        rows = rng.permutation(k + m)[:k]
        A = G[sorted(rows)]
        A_inv = GF256.mat_inv(A)
        assert (GF256.matmul(A_inv, A) == np.eye(k, dtype=np.uint8)).all()


# -------------------------------------------------------------- Reed-Solomon
class TestReedSolomon:
    @FAST
    @given(st.integers(2, 8), st.integers(1, 4), st.integers(0, 2**31 - 1),
           st.integers(16, 400))
    def test_survives_any_m_erasures(self, k, m, seed, L):
        """THE erasure-coding invariant: any <= m lost rows are recoverable."""
        rng = np.random.default_rng(seed)
        rs = ReedSolomon(k, m)
        data = rng.integers(0, 256, (k, L)).astype(np.uint8)
        parity = rs.encode(data)
        full = np.concatenate([data, parity], axis=0)
        lost = rng.permutation(k + m)[:m]
        shards = {i: full[i] for i in range(k + m) if i not in set(lost)}
        for pos in lost:
            rec = rs.recover_block(int(pos), dict(shards))
            assert (rec == full[pos]).all(), f"row {pos} mismatch"

    @FAST
    @given(st.integers(0, 2**31 - 1))
    def test_kernel_path_matches_numpy_path(self, seed):
        rng = np.random.default_rng(seed)
        rs_np = ReedSolomon(4, 2, use_pallas=False)
        rs_pl = ReedSolomon(4, 2, use_pallas=True)
        data = rng.integers(0, 256, (4, 256)).astype(np.uint8)
        assert (rs_np.encode(data) == rs_pl.encode(data)).all()


# ------------------------------------------------------------------- packing
class TestPackingConservation:
    @FAST
    @given(st.lists(st.integers(1, 300), min_size=1, max_size=40),
           st.integers(64, 256), st.integers(0, 2**31 - 1))
    def test_packing_conserves_tokens(self, doc_lens, seq_len, seed):
        """No token is lost or duplicated by the packer (docs longer than
        seq_len are split, not dropped)."""
        from repro.core.ops_format import PackOp
        from repro.core.items import Granularity, IngestItem

        rng = np.random.default_rng(seed)
        docs = [rng.integers(1, 1000, L).astype(np.int32) for L in doc_lens]
        cols = {"tokens": np.array(docs, dtype=object),
                "length": np.array(doc_lens, np.int32)}
        op = PackOp(seq_len=seq_len, rows_per_block=4, pad_id=0)
        outs = op.run([IngestItem(cols, Granularity.CHUNK)])
        total_in = sum(doc_lens)
        total_out = 0
        for it in outs:
            blk = it.data
            cols_out = blk if isinstance(blk, dict) else None
            assert cols_out is not None
            mask = cols_out["segment_ids"] > 0
            total_out += int(mask.sum())
            # positions restart within each segment
            toks = cols_out["tokens"]
            assert toks.shape[1] == seq_len
        assert total_out == total_in

    @FAST
    @given(st.lists(st.integers(1, 200), min_size=2, max_size=30),
           st.integers(0, 2**31 - 1))
    def test_packed_segments_do_not_interleave(self, doc_lens, seed):
        from repro.core.ops_format import PackOp
        from repro.core.items import Granularity, IngestItem

        rng = np.random.default_rng(seed)
        docs = [rng.integers(1, 1000, L).astype(np.int32) for L in doc_lens]
        cols = {"tokens": np.array(docs, dtype=object),
                "length": np.array(doc_lens, np.int32)}
        op = PackOp(seq_len=128, rows_per_block=4, pad_id=0)
        for it in op.run([IngestItem(cols, Granularity.CHUNK)]):
            seg = it.data["segment_ids"]
            for row in seg:
                nz = row[row > 0]
                # segment ids are non-decreasing within a row (contiguous runs)
                assert (np.diff(nz) >= 0).all()


# ----------------------------------------------------------- access invariants
class TestAccessInvariants:
    @FAST
    @given(st.integers(2, 6), st.integers(0, 2**31 - 1))
    def test_split_by_key_is_a_partition(self, num_tasks, seed):
        import tempfile
        from repro.core import DataAccess, DataStore, IngestPlan, create_stage, format_, ingest, select
        from repro.core import store as store_stmt
        from repro.data.generators import as_file_items, gen_lineitem

        tmp = tempfile.mkdtemp()
        ds = DataStore(tmp, nodes=["n0", "n1"])
        p = IngestPlan("t")
        s1 = select(p)
        s2 = format_(p, s1, partition={"scheme": "hash", "key": "suppkey",
                                       "num_partitions": 5},
                     chunk={"target_rows": 128}, serialize="columnar")
        s3 = store_stmt(p, s2, upload=ds)
        create_stage(p, using=[s1, s2, s3])
        ingest(p, as_file_items(gen_lineitem(600, seed=seed % 1000), 2), ds)

        acc = DataAccess(ds)
        splits = acc.split_by_key("partition", num_tasks=num_tasks)
        ids = [e.block_id for s in splits for e in s.blocks]
        assert len(ids) == len(set(ids))            # disjoint
        assert set(ids) == {e.block_id for e in acc.entries}  # exhaustive


# -------------------------------------------------------- label round-trips
class TestLineage:
    @FAST
    @given(st.lists(st.tuples(st.sampled_from(["parser", "replicate", "chunk",
                                               "serialize", "locate"]),
                              st.integers(0, 99)), min_size=1, max_size=8))
    def test_lineage_name_preserves_order(self, labels):
        from repro.core.items import Granularity, IngestItem
        it = IngestItem(b"x", Granularity.FILE)
        for op, v in labels:
            it = it.with_label(op, v)
        name = it.lineage_name()
        parts = name.split("_")
        assert len(parts) == len(labels)
        for (op, v), part in zip(labels, parts):
            assert part.startswith(op)
