"""Lineage-cone recovery, heartbeat liveness, chaos harness (ISSUE 8).

Covers the tentpole's three layers plus its satellites:

* cone compilation (``replay_cone`` / ``cone_replay_capable``) and the two
  runtime trigger sites — a death surfacing at the ingest segment's last
  stage and an ingest contributor found dead at commit;
* the death matrix: kill (SIGTERM) and hang (SIGSTOP) at each edge kind on
  both backends, asserting exactly-once, ``replayed_rows`` strictly below
  the epoch's rows on narrow-edge deaths, and no leaked shm segments or
  spill files;
* the whole-epoch path retained as a correctness *oracle*: the same death
  with ``cone_recovery=False`` must produce byte-identical committed data;
* heartbeat liveness: a SIGSTOP'd worker (pipe still open) is declared
  dead within twice the miss window and the stream completes;
* bounded spawn retry, ``retry_call`` semantics, and the
  ``FaultToleranceDaemon.stop()`` overrun fix;
* the seeded chaos soak on both backends with zero orphans.
"""
import glob
import os
import threading
import time

import pytest

from repro.core import (DataAccess, DataStore, IngestPlan,
                        StreamFaultInjection, StreamingRuntimeEngine,
                        chain_stage, create_stage, resolve_op)
from repro.core.chaos import ChaosController, ChaosEvent, ChaosPlan, chaos_soak
from repro.core.fault import (FaultToleranceDaemon, RecoveryError,
                              RecoveryUDF)
from repro.core.items import IngestItem
from repro.core.liveness import LivenessMonitor, retry_call
from repro.core.plan import cone_replay_capable, segment_split
from repro.core.procexec import ProcessNodeExecutor
from repro.data.generators import gen_lineitem

NODES = ["n0", "n1", "n2", "n3"]
ROWS = 100
EPOCH_ITEMS = 4                       # 1 shard per node per epoch
EPOCH_ROWS = EPOCH_ITEMS * ROWS


def narrow_plan(ds):
    """parse -> chunk+serialize -> upload, all narrow edges (cone-capable)."""
    p = IngestPlan("narrow3")
    s1 = p.add_statement([resolve_op("identity_parser")], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar")],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shuffled_plan(ds):
    """Shuffle at stage a: cone-incapable — deaths must take whole-epoch."""
    p = IngestPlan("shuf")
    s1 = p.add_statement([
        resolve_op("identity_parser"),
        resolve_op("partition", scheme="hash", key="orderkey",
                   num_partitions=4),
        resolve_op("map", fn="repro.core.ops_select:identity_columns",
                   shuffle_by="partition"),
    ], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar")],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shard_source(n_shards, rows=ROWS, delay_s=0.0):
    for i in range(n_shards):
        if delay_s:
            time.sleep(delay_s)
        yield IngestItem(gen_lineitem(rows, seed=i))


def shm_segments():
    return set(glob.glob("/dev/shm/psm_*"))


def assert_clean(ds, before_shm):
    assert not os.listdir(ds.dfs_dir)
    assert ds.gc_orphans() == []
    assert shm_segments() - before_shm == set()


def read_rows(ds):
    cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
    return len(cols["quantity"])


def payload_hashes(ds):
    """Placement-independent content fingerprint: the multiset of committed
    block payload checksums (cone replay may land the dead node's blocks on
    a different survivor, but their bytes must be identical)."""
    import hashlib
    return sorted(hashlib.sha256(ds.read_payload(e.block_id)).hexdigest()
                  for e in ds.blocks() if not e.is_parity)


def arm_signal(eng, fault, stage, state):
    """Fire ``fault`` on the node whose own ``stage`` manifest just landed
    (epoch >= 1, once).  The victim has finished that stage's work — the
    death surfaces at its *next* dispatch, which pins the edge under test."""
    def hook(rnd, src):
        if rnd.stage == stage and rnd.epoch >= 1 and not state.get("victim"):
            state["victim"] = src
            ex = eng.executor(src)
            (ex.kill if fault == "kill" else ex.hang)()
    eng.shuffle.test_on_manifest = hook


# ---------------------------------------------------------------------------
class TestConeCompilation:
    def test_narrow_plan_is_cone_capable(self, store):
        plans = narrow_plan(store).compile()
        split = segment_split(plans)
        assert split == 2
        assert cone_replay_capable(plans, split)

    def test_shuffled_plan_is_not(self, store):
        plans = shuffled_plan(store).compile()
        assert not cone_replay_capable(plans, segment_split(plans))


# ---------------------------------------------------------------------------
class TestLineageCone:
    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_death_after_last_ingest_stage_replays_only_the_cone(
            self, tmp_path, backend):
        """The acceptance scenario: a death surfacing at the ingest
        segment's last stage replays ONLY the dead node's shards —
        strictly fewer rows than the whole epoch — exactly-once."""
        before = shm_segments()
        ds = DataStore(str(tmp_path / backend), nodes=NODES)
        eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend=backend)
        faults = StreamFaultInjection(node_death_at={("n2", 1): "b"})
        rep = eng.run_stream(narrow_plan(ds), shard_source(16), faults=faults)
        eng.close()
        assert rep.committed_epoch_ids() == [0, 1, 2, 3]
        assert "n2" in rep.node_failures
        assert rep.cone_replays() == 1
        # the cone: n2 held 1 of the epoch's 4 shards
        assert 0 < rep.replayed_rows() < EPOCH_ROWS
        assert read_rows(ds) == 16 * ROWS          # no loss, no duplication
        assert_clean(ds, before)

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_mid_segment_death_falls_back_to_whole_epoch(self, tmp_path,
                                                         backend):
        """A death at stage a (NOT the segment's last stage) leaves the
        victim's stage-b work unknowable — the whole-epoch road runs."""
        before = shm_segments()
        ds = DataStore(str(tmp_path / backend), nodes=NODES)
        eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend=backend)
        faults = StreamFaultInjection(node_death_at={("n2", 1): "a"})
        rep = eng.run_stream(narrow_plan(ds), shard_source(16), faults=faults)
        eng.close()
        assert rep.committed_epoch_ids() == [0, 1, 2, 3]
        assert rep.cone_replays() == 0
        assert rep.replayed_epochs == [1]
        assert rep.replayed_rows() == EPOCH_ROWS   # full epoch recomputed
        assert read_rows(ds) == 16 * ROWS
        assert_clean(ds, before)

    def test_cone_disabled_is_byte_identical_oracle(self, tmp_path):
        """Same inputs, same injected death: the cone road's committed
        bytes must equal the whole-epoch oracle's (``cone_recovery=False``)
        — placement aside, block for block."""
        results = {}
        for mode in (True, False):
            ds = DataStore(str(tmp_path / f"cone-{mode}"), nodes=NODES)
            eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                         queue_capacity=8, backend="thread",
                                         cone_recovery=mode)
            faults = StreamFaultInjection(node_death_at={("n2", 1): "b"})
            rep = eng.run_stream(narrow_plan(ds), shard_source(16),
                                 faults=faults)
            eng.close()
            assert rep.committed_epoch_ids() == [0, 1, 2, 3]
            assert rep.cone_replays() == (1 if mode else 0)
            results[mode] = payload_hashes(ds)
            assert read_rows(ds) == 16 * ROWS
        assert results[True] == results[False]


# ---------------------------------------------------------------------------
class TestDeathMatrix:
    """kill (SIGTERM) / hang (SIGSTOP) x edge kind x backend.

    The signal fires at the victim's own manifest for the stage *feeding*
    the edge under test, so the death surfaces while that edge's round is
    the live dependency.  A thread executor cannot be stopped or killed
    independently of the coordinator, so on the thread backend the matrix
    runs with injected deaths at the same surface (hang == kill there, see
    ``ChaosPlan.stream_faults``)."""

    MATRIX = [(edge, fault, backend)
              for edge in ("narrow", "shuffle", "cross-segment")
              for fault in ("kill", "hang")
              for backend in ("thread", "process")]

    @pytest.mark.parametrize("edge,fault,backend", MATRIX)
    def test_death_matrix(self, tmp_path, edge, fault, backend):
        if backend == "thread" and fault == "hang":
            pytest.skip("thread executors cannot wedge independently of the "
                        "coordinator; hang renders as kill (chaos DSL)")
        before = shm_segments()
        ds = DataStore(str(tmp_path / f"{edge}-{fault}-{backend}"),
                       nodes=NODES)
        plan = shuffled_plan(ds) if edge == "shuffle" else narrow_plan(ds)
        hb = dict(heartbeat_interval_s=0.05, heartbeat_miss=3) \
            if (backend == "process" and fault == "hang") else {}
        eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend=backend, **hb)
        state = {}
        faults = None
        if backend == "thread":
            # injected death after the stage feeding the edge's consumer
            stage = {"narrow": "b", "shuffle": "b", "cross-segment": "c"}[edge]
            state["victim"] = "n2"
            faults = StreamFaultInjection(node_death_at={("n2", 1): stage})
        else:
            eng.prewarm_executors()
            # narrow/shuffle: die right after stage a (next dispatch = the
            # consumer across the a->b edge); cross-segment: after stage b
            # (next dispatch = the store slice across the segment boundary)
            stage = "b" if edge == "cross-segment" else "a"
            arm_signal(eng, fault, stage, state)
        rep = eng.run_stream(plan, shard_source(16, delay_s=0.01),
                             faults=faults)
        eng.close()
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        victim = state["victim"]
        assert victim and victim in rep.node_failures
        assert read_rows(ds) == 16 * ROWS          # exactly-once, always
        if edge == "narrow" and backend == "thread":
            # deterministic cone road: strictly fewer rows than the epoch
            assert rep.cone_replays() >= 1
            assert 0 < rep.replayed_rows() < EPOCH_ROWS
        if edge == "shuffle":
            assert rep.cone_replays() == 0         # cone-incapable plan
        if backend == "process" and fault == "hang":
            assert [d for d in rep.liveness_deaths if d[0] == victim]
        assert_clean(ds, before)

    def test_sigterm_after_stage_a_takes_cone_road(self, store):
        """Real SIGTERM, narrow plan: the victim dies having finished
        stage a; its stage-b dispatch fails and only its cone replays."""
        before = shm_segments()
        eng = StreamingRuntimeEngine(store, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend="process")
        eng.prewarm_executors()
        state = {}
        arm_signal(eng, "kill", "a", state)
        rep = eng.run_stream(narrow_plan(store),
                             shard_source(16, delay_s=0.01))
        eng.close()
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        assert state["victim"] in rep.node_failures
        assert rep.cone_replays() >= 1
        assert 0 < rep.replayed_rows() < EPOCH_ROWS
        assert read_rows(store) == 16 * ROWS
        assert_clean(store, before)


# ---------------------------------------------------------------------------
class TestHeartbeatLiveness:
    def test_sigstop_worker_declared_dead_within_miss_window(self, store):
        """A SIGSTOP'd worker keeps its pipe open — only the heartbeat can
        see it.  It must be declared dead within twice the miss window and
        the stream must still commit every epoch exactly-once."""
        before = shm_segments()
        interval, miss = 0.05, 3
        eng = StreamingRuntimeEngine(store, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend="process",
                                     heartbeat_interval_s=interval,
                                     heartbeat_miss=miss)
        eng.prewarm_executors()
        state = {}
        arm_signal(eng, "hang", "a", state)
        rep = eng.run_stream(narrow_plan(store),
                             shard_source(16, delay_s=0.01))
        eng.close()
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        victim = state["victim"]
        deaths = [d for d in rep.liveness_deaths if d[0] == victim]
        assert deaths, "liveness monitor never declared the stopped worker"
        assert deaths[0][1] <= 2 * interval * miss
        assert victim in rep.node_failures
        assert read_rows(store) == 16 * ROWS
        assert_clean(store, before)

    def test_monitor_skips_executors_without_heartbeat_surface(self):
        mon = LivenessMonitor(interval_s=0.05, miss_threshold=2)
        assert mon.watch("n0", object()) is False
        mon.start()
        mon.stop()
        assert mon.deaths == []

    def test_retry_call_retries_then_succeeds(self):
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise OSError("transient")
            return "ok"

        out, used = retry_call(flaky, attempts=3, base_delay_s=0.001)
        assert out == "ok" and used == 3

    def test_retry_call_reraises_after_budget(self):
        with pytest.raises(OSError):
            retry_call(lambda: (_ for _ in ()).throw(OSError("x")),
                       attempts=2, base_delay_s=0.001)

    def test_retry_call_only_retries_declared_exceptions(self):
        calls = []

        def broken():
            calls.append(1)
            raise ValueError("programming error")

        with pytest.raises(ValueError):
            retry_call(broken, attempts=3, base_delay_s=0.001)
        assert len(calls) == 1

    def test_spawn_retry_bounded_and_reported(self, tmp_path):
        """First spawn attempt of every worker fails with a transient
        OSError; the bounded retry recovers and the report counts it."""
        failed = set()

        def fault(node, attempt):
            if attempt == 1:
                failed.add(node)
                raise OSError(f"transient fork failure on {node}")

        ds = DataStore(str(tmp_path / "s"), nodes=NODES)
        eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend="process")
        ProcessNodeExecutor.spawn_fault = fault
        try:
            rep = eng.run_stream(narrow_plan(ds), shard_source(8))
        finally:
            ProcessNodeExecutor.spawn_fault = None
            eng.close()
        assert rep.committed_epoch_ids() == [0, 1]
        assert len(failed) == len(NODES)
        assert rep.spawn_retries == len(NODES)
        assert read_rows(ds) == 8 * ROWS

    def test_spawn_gives_up_after_budget(self, tmp_path):
        def always(node, attempt):
            raise OSError("persistent")

        ds = DataStore(str(tmp_path / "s"), nodes=["n0"])
        ProcessNodeExecutor.spawn_fault = always
        try:
            with pytest.raises(OSError):
                ProcessNodeExecutor("n0", ds)
        finally:
            ProcessNodeExecutor.spawn_fault = None


# ---------------------------------------------------------------------------
class TestDaemonStop:
    """Satellite: stop() used to join(timeout=5) and silently leak the
    poller when a recovery backlog outlived the timeout."""

    class _SlowUDF(RecoveryUDF):
        name = "slow"

        def __init__(self, delay_s):
            self.delay_s = delay_s

        def detect(self, store, failed):
            time.sleep(self.delay_s)
            raise RecoveryError("never recovers")

    def _corrupt_some(self, ds, n=4):
        from repro.core import RuntimeEngine
        eng = RuntimeEngine(ds)
        eng.run(narrow_plan(ds), list(shard_source(8)))
        eng.close()
        victims = [e.block_id for e in ds.blocks()][:n]
        for bid in victims:
            ds.corrupt_block(bid)
        return victims

    def test_stop_aborts_backlogged_sweep(self, tmp_path):
        """A stop request lands mid-sweep: the per-block stop check aborts
        the backlog promptly instead of riding out every slow block."""
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
        victims = self._corrupt_some(ds, n=4)
        daemon = FaultToleranceDaemon(ds, [self._SlowUDF(0.15)],
                                      poll_interval_s=0.01)
        daemon.start()
        time.sleep(0.05)               # poller is inside block 1 of 4
        t = daemon._thread
        assert daemon.stop(timeout_s=1.0) is True
        assert not t.is_alive()
        assert daemon.report.stop_overrun is False
        # the sweep aborted early: the full backlog would need ~0.6s
        handled = (len(daemon.report.recovered)
                   + len(daemon.report.unrecoverable))
        assert handled < len(victims)

    def test_stop_overrun_is_surfaced_not_swallowed(self, tmp_path):
        """When the join deadline expires while a UDF is still running,
        stop() reports the overrun instead of pretending quiescence."""
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
        self._corrupt_some(ds, n=2)
        daemon = FaultToleranceDaemon(ds, [self._SlowUDF(0.5)],
                                      poll_interval_s=0.01)
        daemon.start()
        time.sleep(0.05)               # inside the first slow detect()
        t = daemon._thread
        assert daemon.stop(timeout_s=0.05) is False
        assert daemon.report.stop_overrun is True
        t.join(timeout=2)              # exits at its next stop check
        assert not t.is_alive()


# ---------------------------------------------------------------------------
class TestChaosPlan:
    def test_generation_is_deterministic(self):
        kw = dict(epochs=10, nodes=NODES, stages=["a", "b"], kills=2,
                  hangs=1, delays=2, garbles=3)
        assert (ChaosPlan.generate(5, **kw).events
                == ChaosPlan.generate(5, **kw).events)
        assert (ChaosPlan.generate(5, **kw).events
                != ChaosPlan.generate(6, **kw).events)

    def test_lethal_budget_keeps_survivors(self):
        p = ChaosPlan.generate(1, epochs=5, nodes=NODES, stages=["a"],
                               kills=10, hangs=10)
        lethal = [e for e in p.events if e.kind in ("kill", "hang")]
        assert len(lethal) == len(NODES) - 2
        assert len({e.node for e in lethal}) == len(lethal)

    def test_garbles_stay_under_dummy_substitution(self):
        p = ChaosPlan.generate(3, epochs=5, nodes=NODES, stages=["a", "b"],
                               kills=0, hangs=0, delays=0, garbles=50)
        per_op = {}
        for e in p.events:
            assert e.kind == "garble"
            key = (e.stage, e.op_index)
            per_op[key] = per_op.get(key, 0) + e.count
        # < default max_retries=3: absorbed by retry, never dummy-substituted
        assert all(c <= 2 for c in per_op.values())

    def test_render_kills_and_garbles_for_stream(self):
        p = ChaosPlan([ChaosEvent("kill", 2, "b", "n1"),
                       ChaosEvent("hang", 3, "a", "n2"),
                       ChaosEvent("garble", 0, "a", "n0", count=2)])
        sf = p.stream_faults("thread")
        assert sf.node_death_at == {("n1", 2): "b", ("n2", 3): "a"}
        assert sf.op_failures == {("a", 0): 2}
        sfp = p.stream_faults("process")     # hang stays a real signal
        assert sfp.node_death_at == {("n1", 2): "b"}

    def test_render_for_batch_engine(self):
        p = ChaosPlan([ChaosEvent("kill", 0, "a", "n1"),
                       ChaosEvent("garble", 0, "b", "n0")])
        bf = p.batch_faults()
        assert bf.node_death_after_stage == {"n1": "a"}
        assert bf.op_failures == {("b", 0): 1}

    def test_arm_fail_next_drives_legacy_hook(self, store):
        plans = narrow_plan(store).compile()
        p = ChaosPlan([ChaosEvent("garble", 0, "b", "n0", op_index=0,
                                  count=2)])
        assert p.arm_fail_next(plans) == 1
        assert plans[1].ops[0]._fail_next == 2

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            ChaosEvent("explode", 0, "a", "n0")


# ---------------------------------------------------------------------------
class TestChaosSoak:
    def test_soak_thread_backend(self):
        res = chaos_soak(backend="thread", epochs=20)
        assert res.ok, res.errors
        assert res.rows_in == res.rows_out
        assert res.node_failures >= 2
        assert res.cone_replays >= 1       # default seed covers the cone road
        assert res.orphans == [] and res.shm_leaked == []

    def test_soak_process_backend(self):
        res = chaos_soak(backend="process", epochs=20)
        assert res.ok, res.errors
        assert res.rows_in == res.rows_out
        assert res.liveness_deaths >= 1    # the scheduled SIGSTOP was caught
        assert res.orphans == [] and res.shm_leaked == []

    def test_controller_fires_each_signal_event_once(self, store):
        eng = StreamingRuntimeEngine(store, epoch_items=EPOCH_ITEMS,
                                     queue_capacity=8, backend="thread")
        plan = ChaosPlan([ChaosEvent("delay", 1, "a", "n1", seconds=0.0)])
        ctl = ChaosController(plan, eng, base_eid=store.next_epoch_id(),
                              backend="thread").attach()
        rep = eng.run_stream(narrow_plan(store), shard_source(8))
        ctl.detach()
        eng.close()
        assert rep.committed_epoch_ids() == [0, 1]
        assert [e.kind for e in ctl.fired] == ["delay"]


# ---------------------------------------------------------------------------
class TestRecoveryPerfGate:
    """recovery_ms gates LOWER-is-better: a latency *rise* beyond the
    threshold is the regression (perf_gate inverts the drop to
    ``fresh/base - 1`` for metrics in ``LOWER_IS_BETTER``)."""

    def _write(self, path, entries):
        import json
        with open(path, "w") as f:
            json.dump(entries, f)

    def test_latency_rise_is_a_regression(self, tmp_path):
        from benchmarks.perf_gate import check
        traj = str(tmp_path / "t.json")
        self._write(traj, [
            {"scale": 1000, "recovery_ms": 10.0},
            {"scale": 1000, "recovery_ms": 20.0},
        ])
        code, msg = check(traj, metric="recovery_ms")
        assert code == 1 and "REGRESSION" in msg

    def test_latency_drop_passes(self, tmp_path):
        from benchmarks.perf_gate import check
        traj = str(tmp_path / "t.json")
        self._write(traj, [
            {"scale": 1000, "recovery_ms": 20.0},
            {"scale": 1000, "recovery_ms": 10.0},
        ])
        code, msg = check(traj, metric="recovery_ms")
        assert code == 0 and "OK" in msg

    def test_throughput_direction_unchanged(self, tmp_path):
        """The inversion applies ONLY to LOWER_IS_BETTER metrics — a
        throughput rise must still pass."""
        from benchmarks.perf_gate import check
        traj = str(tmp_path / "t.json")
        self._write(traj, [
            {"scale": 1000, "pipelined_rows_per_s": 100.0},
            {"scale": 1000, "pipelined_rows_per_s": 200.0},
        ])
        code, msg = check(traj, metric="pipelined_rows_per_s")
        assert code == 0 and "OK" in msg

    def test_recovery_ms_in_default_metric_set(self):
        from benchmarks.perf_gate import DEFAULT_METRICS, LOWER_IS_BETTER
        assert "recovery_ms" in DEFAULT_METRICS
        assert "recovery_ms" in LOWER_IS_BETTER

    def test_missing_recovery_history_skips_cleanly(self, tmp_path):
        from benchmarks.perf_gate import main
        traj = str(tmp_path / "t.json")
        self._write(traj, [
            {"scale": 1000, "pipelined_rows_per_s": 100.0},
            {"scale": 1000, "pipelined_rows_per_s": 101.0,
             "recovery_ms": 12.0},
        ])
        assert main(["--file", traj]) == 0
