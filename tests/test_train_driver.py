"""Integration: the end-to-end train driver — ingest -> feed -> train ->
checkpoint -> crash -> resume (the fault-tolerant restart path)."""
import sys

import pytest


def run_train(tmp_path, extra):
    from repro.launch.train import main
    argv = sys.argv
    sys.argv = ["train", "--arch", "smollm-135m", "--smoke",
                "--batch", "4", "--seq-len", "128", "--docs", "300",
                "--data-dir", str(tmp_path / "corpus"),
                "--ckpt-dir", str(tmp_path / "ckpt"),
                "--log-every", "1000"] + extra
    try:
        return main()
    finally:
        sys.argv = argv


@pytest.mark.slow
def test_train_checkpoint_resume(tmp_path):
    # phase 1: train 12 steps, checkpoint every 6 (loss-decrease over such a
    # short run is noise — convergence is asserted by examples/train_smollm)
    rc = run_train(tmp_path, ["--steps", "12", "--ckpt-every", "6"])
    assert rc in (0, 1)
    from repro.training.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    assert mgr.latest_step() == 12

    # phase 2: "the job died" — resume from the latest checkpoint
    rc = run_train(tmp_path, ["--steps", "6", "--ckpt-every", "6", "--resume"])
    assert mgr.latest_step() == 18  # continued, didn't restart from 0
