"""Worker-pull sources (ISSUE 6): descriptors instead of item pushes.

Covers the adapter units (file ranges, directory tail, socket lines, seeded
generators), the acceptance invariant (``source_coordinator_bytes == 0`` on
both backends, with the legacy pushed path keeping the counter live), the
``SOURCE ...`` language surface, the fixed wall-clock epoch cutter (deadline
arms on entry; an empty tick no longer ends the stream), and the descriptor
replay fault matrix — injected deaths and real SIGTERMs mid-shard-read and
mid-parse must stay exactly-once, observe ``source_reissues``, and leak no
shm segments or spill files.
"""
import glob
import os
import socket
import threading
import time

import numpy as np
import pytest

from repro.core import (DataAccess, DataStore, DirectoryTailSource,
                        FileRangeSource, GeneratorSpecSource, IngestPlan,
                        IngestQueues, LanguageSession, ShardDescriptor,
                        SocketLineSource, StreamFaultInjection,
                        StreamingRuntimeEngine, build_source, chain_stage,
                        create_stage, parse_numeric_lines, resolve_op,
                        stream_ingest, unparse_source, with_epochs,
                        with_source, write_numeric_file)
from repro.core.language import LanguageError, format_, select
from repro.core.language import store as store_stmt
from repro.core.items import IngestItem
from repro.data.generators import gen_lineitem

GEN = "repro.data.generators:gen_lineitem"


def columnar_plan(ds, *, epoch_items=4):
    """Single-stage parse -> chunk -> serialize -> store plan."""
    p = IngestPlan("pull")
    s1 = select(p, parser="identity_parser")
    s2 = format_(p, s1, chunk={"target_rows": 64}, serialize="columnar")
    s3 = store_stmt(p, s2, locate="roundrobin", upload=ds)
    create_stage(p, using=[s1, s2, s3], name="main")
    return with_epochs(p, items=epoch_items)


def narrow3_plan(ds, *, epoch_items=4):
    """Three narrow stages (a -> b -> c): the read happens in stage a, the
    parse/serialize pipeline in b — a kill between them lands mid-parse."""
    p = IngestPlan("pull3")
    s1 = p.add_statement([resolve_op("identity_parser")], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar")],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return with_epochs(p, items=epoch_items)


def agg(rep, field):
    return sum(getattr(e.run, field) for e in rep.epochs)


def shm_segments():
    return set(glob.glob("/dev/shm/psm_*"))


def lineitem_file(path, rows, seed=0):
    cols = gen_lineitem(rows, seed=seed)
    size = write_numeric_file(str(path), cols)
    return cols, size


# ---------------------------------------------------------------------------
class TestAdapters:
    def test_file_range_split_preserves_every_row(self, tmp_path):
        """Hadoop-style splits: each range owns lines starting inside it, so
        any shard_bytes reassembles the exact row set."""
        cols, size = lineitem_file(tmp_path / "d.csv", 300, seed=3)
        for shard_bytes in (size, size // 2, size // 7, 64):
            src = FileRangeSource(str(tmp_path / "d.csv"), fields=list(cols),
                                  shard_bytes=shard_bytes)
            descs = src.describe()
            assert [d.spec["start"] for d in descs] == sorted(
                d.spec["start"] for d in descs)
            got = np.concatenate([it.data["quantity"]
                                  for d in descs for it in src.read(d)])
            assert sorted(got.tolist()) == sorted(cols["quantity"].tolist())

    def test_file_range_read_is_deterministic(self, tmp_path):
        cols, size = lineitem_file(tmp_path / "d.csv", 100)
        src = FileRangeSource(str(tmp_path / "d.csv"), fields=list(cols),
                              shard_bytes=size // 3)
        d = src.describe()[1]
        a, b = src.read(d), src.read(d)   # replay must re-yield the same rows
        np.testing.assert_array_equal(a[0].data["quantity"],
                                      b[0].data["quantity"])

    def test_generator_spec_descriptors_and_replay(self):
        src = GeneratorSpecSource(GEN, shards=5, rows=40, seed=9)
        descs = src.describe()
        assert len(descs) == 5
        assert all(isinstance(d, ShardDescriptor) for d in descs)
        assert [d.spec["seed"] for d in descs] == [9, 10, 11, 12, 13]
        a, b = src.read(descs[2]), src.read(descs[2])
        np.testing.assert_array_equal(a[0].data["quantity"],
                                      b[0].data["quantity"])
        assert a[0].nrows() == 40

    def test_generator_spec_fails_fast_on_bad_import(self):
        with pytest.raises(Exception):
            GeneratorSpecSource("no.such.module:fn", shards=1, rows=1)

    def test_directory_tail_polls_new_files_then_exhausts(self, tmp_path):
        d = tmp_path / "landing"
        d.mkdir()
        cols, _ = lineitem_file(d / "a.csv", 50)
        src = DirectoryTailSource(str(d), pattern="*.csv", fields=list(cols),
                                  idle_timeout_s=0.2)
        first = src.describe()
        assert len(first) == 1 and not src.exhausted()
        assert src.poll() == []                      # nothing new yet
        lineitem_file(d / "b.csv", 50, seed=1)
        fresh = src.poll()
        assert len(fresh) == 1 and fresh[0].spec["path"].endswith("b.csv")
        time.sleep(0.25)
        assert src.exhausted()                       # idle window elapsed
        got = sum(it.nrows() for ds_ in (first, fresh)
                  for dd in ds_ for it in src.read(dd))
        assert got == 100

    def test_socket_line_source_drains_endpoint(self):
        cols = gen_lineitem(30, seed=4)
        payload = "\n".join(
            ",".join(repr(cols[c][i].item()) for c in cols)
            for i in range(30)).encode() + b"\n"
        srv = socket.create_server(("127.0.0.1", 0))
        port = srv.getsockname()[1]

        def serve():
            conn, _ = srv.accept()
            conn.sendall(payload)
            conn.close()

        t = threading.Thread(target=serve, daemon=True)
        t.start()
        src = SocketLineSource([f"127.0.0.1:{port}"], fields=list(cols))
        descs = src.describe()
        assert descs[0].spec == {"host": "127.0.0.1", "port": port}
        items = src.read(descs[0])
        t.join(timeout=5)
        srv.close()
        assert items[0].nrows() == 30
        np.testing.assert_array_equal(items[0].data["quantity"],
                                      cols["quantity"])

    def test_parse_numeric_lines_integral_columns_stay_int(self):
        out = parse_numeric_lines(["1,2.5", "3,4.5"], ["a", "b"])
        assert out["a"].dtype == np.int64 and out["b"].dtype == np.float64

    def test_build_source_rejects_unknown_kind(self):
        with pytest.raises(ValueError, match="unknown source kind"):
            build_source({"kind": "nope"})


# ---------------------------------------------------------------------------
class TestLanguageSurface:
    def test_source_statement_sets_plan_spec(self):
        sess = LanguageSession()
        sess.execute(f"""
            s1 = SELECT * FROM input;
            CREATE STAGE main USING s1;
            STREAM WITH EPOCHS(items=4);
            SOURCE generator(spec={GEN}, shards=4, rows=5);
        """)
        spec = sess.plan.source_spec
        assert spec == {"kind": "generator", "spec": GEN,
                        "shards": 4, "rows": 5}
        assert sess.plan.signature()["source"] == spec

    def test_source_unparse_roundtrip(self):
        p = IngestPlan("rt")
        with_source(p, "generator", spec=GEN, shards=3, rows=7)
        text = unparse_source(p)
        sess = LanguageSession()
        sess.execute("s1 = SELECT * FROM input; " + text)
        assert sess.plan.source_spec == p.source_spec

    def test_source_unparse_roundtrip_fields_tuple(self, tmp_path):
        lineitem_file(tmp_path / "rt.csv", 10)
        p = IngestPlan("rt2")
        with_source(p, "files", paths=str(tmp_path / "rt.csv"),
                    shard_bytes=2048, fields=("orderkey", "quantity"))
        text = unparse_source(p)
        assert "fields=orderkey|quantity" in text
        sess = LanguageSession()
        sess.execute("s1 = SELECT * FROM input; " + text)
        assert sess.plan.source_spec == p.source_spec

    def test_source_statement_size_literals_and_fields(self, tmp_path):
        lineitem_file(tmp_path / "d.csv", 10)
        sess = LanguageSession()
        sess.execute(f"SOURCE files(paths={tmp_path}/d.csv, shard_bytes=1kb, "
                     f"fields=orderkey|quantity);")
        assert sess.plan.source_spec["shard_bytes"] == 1024
        assert sess.plan.source_spec["fields"] == ("orderkey", "quantity")

    def test_bad_source_fails_at_declaration(self):
        with pytest.raises(LanguageError, match="SOURCE"):
            LanguageSession().execute("SOURCE nosuchkind(x=1);")
        with pytest.raises(LanguageError):
            # known kind, bad kwarg: the eager validation build catches it
            IngestPlan("x") and with_source(IngestPlan("x"), "generator",
                                            bogus=1)


# ---------------------------------------------------------------------------
class TestZeroSourceCoordinatorBytes:
    """Acceptance: descriptor-backed sources move zero item bytes through
    the coordinator on BOTH backends; the pushed path keeps the counter
    live (it is a measurement, not a vacuous zero)."""

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_generator_source_is_zero(self, tmp_path, backend):
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2", "n3"])
        src = GeneratorSpecSource(GEN, shards=8, rows=50)
        rep = stream_ingest(columnar_plan(ds), src, ds, backend=backend)
        assert rep.source_coordinator_bytes() == 0
        assert rep.source_descriptors() == 8
        assert rep.source_reissues() == 0
        assert rep.total_items == 8          # worker-reported counts
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 50

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_file_source_is_zero(self, tmp_path, backend):
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])
        cols, size = lineitem_file(tmp_path / "d.csv", 400)
        src = FileRangeSource(str(tmp_path / "d.csv"), fields=list(cols),
                              shard_bytes=max(1, size // 10))
        rep = stream_ingest(columnar_plan(ds), src, ds, backend=backend)
        assert rep.source_coordinator_bytes() == 0
        assert rep.source_descriptors() >= 10
        got = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert sorted(got["quantity"].tolist()) == sorted(
            cols["quantity"].tolist())

    def test_plan_level_source_spec_compiles_to_adapter(self, tmp_path):
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])
        p = columnar_plan(ds)
        with_source(p, "generator", spec=GEN, shards=6, rows=20)
        rep = stream_ingest(p, None, ds)     # no source arg: the plan has one
        assert rep.source_coordinator_bytes() == 0
        assert rep.total_items == 6
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 6 * 20

    def test_sequential_mode_pulls_too(self, tmp_path):
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])
        src = GeneratorSpecSource(GEN, shards=7, rows=10)
        rep = stream_ingest(columnar_plan(ds, epoch_items=3), src, ds,
                            pipelined=False)
        assert rep.source_coordinator_bytes() == 0
        assert rep.source_descriptors() == 7
        assert [e.items_in for e in rep.epochs] == [3, 3, 1]

    def test_pushed_path_counts_coordinator_bytes(self, tmp_path):
        """The legacy oracle: pushed iterators still cross the coordinator
        and the new counter observes every byte of it."""
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])
        items = [IngestItem(gen_lineitem(50, seed=i)) for i in range(6)]
        rep = stream_ingest(columnar_plan(ds), iter(items), ds)
        assert rep.source_coordinator_bytes() == sum(
            it.nbytes() for it in items)
        assert rep.source_descriptors() == 0

    def test_directory_tail_streams_arrivals(self, tmp_path):
        """Unbounded intake: files landing mid-stream become descriptors via
        poll(); the stream ends at the idle timeout."""
        d = tmp_path / "landing"
        d.mkdir()
        cols, _ = lineitem_file(d / "a.csv", 60)
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])
        src = DirectoryTailSource(str(d), pattern="*.csv", fields=list(cols),
                                  idle_timeout_s=1.2)

        def land_late():
            time.sleep(0.1)
            lineitem_file(d / "b.csv", 60, seed=1)

        t = threading.Thread(target=land_late, daemon=True)
        t.start()
        rep = stream_ingest(columnar_plan(ds, epoch_items=1), src, ds)
        t.join()
        assert rep.source_coordinator_bytes() == 0
        got = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(got["quantity"]) == 120


# ---------------------------------------------------------------------------
class TestEpochCutterFix:
    """Satellite: cut_epoch's tick deadline arms on entry, so idle streams
    honor wall-clock cuts — and an empty tick no longer terminates the
    stream (at_eof distinguishes the two)."""

    def test_idle_queue_honors_wallclock_cut(self):
        q = IngestQueues.manual(["n0", "n1"])
        t0 = time.monotonic()
        batch = q.cut_epoch(1000, tick_s=0.05)
        elapsed = time.monotonic() - t0
        assert all(not v for v in batch.values())
        assert 0.04 <= elapsed < 1.0       # returned at the deadline, no hang
        assert not q.at_eof()              # empty tick is NOT end-of-stream
        q.close()
        assert q.at_eof()

    def test_trickle_does_not_hold_epoch_open(self, tmp_path):
        """A source whose first item arrives well after the tick: the old
        cutter waited forever for item #1 before arming; empty ticks must
        now spin through until data lands, then cut — without ending the
        stream early."""
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])

        def late_source():
            time.sleep(0.2)                 # several empty 0.03 s ticks
            for i in range(3):
                yield IngestItem(gen_lineitem(20, seed=i))

        p = columnar_plan(ds, epoch_items=100)
        p.stream_config["seconds"] = 0.03
        rep = stream_ingest(p, late_source(), ds)
        assert rep.total_items == 3         # nothing lost to the empty ticks
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 60

    def test_wallclock_cut_splits_slow_pull_stream(self, tmp_path):
        """Descriptor cutter: with a seconds policy, a slow unbounded-ish
        adapter cuts multiple small epochs instead of one giant one."""
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])
        src = GeneratorSpecSource(GEN, shards=6, rows=10, delay_s=0.02)
        p = columnar_plan(ds, epoch_items=1000)
        p.stream_config["seconds"] = 0.01
        rep = stream_ingest(p, src, ds)
        assert len(rep.epochs) >= 1
        assert rep.total_items == 6
        assert agg(rep, "source_coordinator_bytes") == 0


# ---------------------------------------------------------------------------
class TestDescriptorReplayFaultMatrix:
    """Satellite: reader death — injected and real SIGTERM, mid-shard-read
    and mid-parse — re-issues the dead node's descriptors to survivors and
    commits exactly-once, with no leaked shm segments or spill files."""

    def test_injected_death_reissues_descriptors(self, tmp_path):
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])
        src = GeneratorSpecSource(GEN, shards=16, rows=50)
        faults = StreamFaultInjection(node_death_in_epoch={"n2": 1})
        rep = stream_ingest(columnar_plan(ds), src, ds, faults=faults)
        assert rep.committed_epoch_ids() == [0, 1, 2, 3]
        assert rep.replayed_epochs == [1]
        assert rep.node_failures == ["n2"]
        assert rep.source_reissues() >= 1
        assert rep.source_coordinator_bytes() == 0
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 16 * 50    # no loss, no duplication
        assert ds.gc_orphans() == []

    def test_sigterm_mid_shard_read_process_backend(self, tmp_path):
        """Kill a process worker while it sleeps inside adapter.read — the
        epoch's descriptors re-issue to survivors, commits stay gap-free."""
        before = shm_segments()
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])
        src = GeneratorSpecSource(GEN, shards=16, rows=50, delay_s=0.05)
        eng = StreamingRuntimeEngine(ds, epoch_items=4, backend="process")
        eng.prewarm_executors()
        killed = []

        def killer():
            time.sleep(0.15)                 # mid-stream, mid-read
            killed.append("n1")
            eng.executor("n1").kill()

        t = threading.Thread(target=killer, daemon=True)
        t.start()
        rep = eng.run_stream(columnar_plan(ds), src)
        t.join()
        eng.close()
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        assert "n1" in rep.node_failures
        assert rep.source_reissues() >= 1
        assert rep.source_coordinator_bytes() == 0
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 16 * 50
        assert not os.listdir(ds.dfs_dir)
        assert ds.gc_orphans() == []         # any torn source spill reclaimed
        assert shm_segments() - before == set()

    def test_sigterm_mid_parse_process_backend(self, tmp_path):
        """Kill after the read stage's manifest (stage a done, parse pipeline
        b mid-flight) on a file-range source: the replay re-reads the dead
        node's byte ranges on survivors, exactly-once."""
        before = shm_segments()
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])
        cols, size = lineitem_file(tmp_path / "d.csv", 800)
        src = FileRangeSource(str(tmp_path / "d.csv"), fields=list(cols),
                              shard_bytes=max(1, size // 16), delay_s=0.01)
        eng = StreamingRuntimeEngine(ds, epoch_items=4, backend="process")
        eng.prewarm_executors()
        killed = []

        def kill_mid_parse(rnd, src_node):
            # a narrow manifest of epoch >= 1 means the sender finished the
            # read stage: SIGTERM a peer with its parse stage still pending
            if rnd.epoch >= 1 and rnd.key is None and not killed:
                victim = next(n for n in rnd.targets if n != src_node)
                killed.append(victim)
                eng.executor(victim).kill()

        eng.shuffle.test_on_manifest = kill_mid_parse
        rep = eng.run_stream(narrow3_plan(ds), src)
        eng.close()
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids)))
        assert killed and killed[0] in rep.node_failures
        assert rep.source_reissues() >= 1
        assert rep.source_coordinator_bytes() == 0
        got = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert sorted(got["quantity"].tolist()) == sorted(
            cols["quantity"].tolist())
        assert not os.listdir(ds.dfs_dir)
        assert ds.gc_orphans() == []
        assert shm_segments() - before == set()

    def test_thread_backend_death_mid_pull(self, tmp_path):
        """Same replay discipline on the thread backend (injected death in a
        multi-stage pulled plan: the read ran in the ingest segment)."""
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1", "n2", "n3"])
        src = GeneratorSpecSource(GEN, shards=12, rows=40)
        faults = StreamFaultInjection(node_death_in_epoch={"n3": 0})
        rep = stream_ingest(narrow3_plan(ds), src, ds, faults=faults)
        assert rep.node_failures == ["n3"]
        assert rep.source_reissues() >= 1
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 12 * 40
        assert ds.gc_orphans() == []


# ---------------------------------------------------------------------------
class TestPerfGatePullMetric:
    def test_pull_metric_is_gated_by_default(self, tmp_path):
        import json
        from benchmarks.perf_gate import DEFAULT_METRICS, main
        assert "pull_rows_per_s" in DEFAULT_METRICS
        traj = str(tmp_path / "t.json")
        with open(traj, "w") as f:
            json.dump([
                {"scale": 1000, "pipelined_rows_per_s": 100.0,
                 "pull_rows_per_s": 100.0},
                {"scale": 1000, "pipelined_rows_per_s": 100.0,
                 "pull_rows_per_s": 50.0},
            ], f)
        assert main(["--file", traj]) == 1      # pull regression gates
        # histories that predate the metric skip cleanly
        with open(traj, "w") as f:
            json.dump([
                {"scale": 1000, "pipelined_rows_per_s": 100.0},
                {"scale": 1000, "pipelined_rows_per_s": 100.0,
                 "pull_rows_per_s": 50.0},
            ], f)
        assert main(["--file", traj]) == 0
