"""Pipelined execution core (ISSUE 2): persistent node executors, the
DataStore commit sequencer, async double-buffered shuffle, overlapped epochs,
feed fan-out, orphan GC, and the unrouted-item guarantee."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (DataAccess, DataStore, FeedSpec, IngestPlan,
                        IngestQueues, StreamFaultInjection,
                        StreamingRuntimeEngine, chain_stage, create_stage,
                        format_, parse_feed_script, resolve_op, select,
                        split_pipeline_segments, stream_ingest_multi,
                        with_epochs)
from repro.core import store as store_stmt
from repro.core.items import Granularity, IngestItem
from repro.core.language import LanguageError
from repro.data.generators import gen_lineitem


def columnar_plan(ds, *, name="stream", epoch_items=None):
    p = IngestPlan(name)
    s1 = select(p)
    s2 = format_(p, s1, chunk={"target_rows": 256}, serialize="columnar")
    s3 = store_stmt(p, s2, locate="roundrobin",
                    locate_args={"num_locations": len(ds.nodes)}, upload=ds)
    create_stage(p, using=[s1, s2, s3], name="main")
    if epoch_items is not None:
        with_epochs(p, items=epoch_items)
    return p


def shuffled_plan(ds):
    """Three stages: ingest segment (parse+partition+shuffle, then
    chunk+serialize) and store segment (upload) — the overlap split."""
    p = IngestPlan("shuf")
    s1 = p.add_statement([
        resolve_op("identity_parser"),
        resolve_op("partition", scheme="hash", key="orderkey", num_partitions=4),
        resolve_op("map", fn=lambda cols: cols, shuffle_by="partition"),
    ], kind="select")
    s2 = p.add_statement([
        resolve_op("chunk", target_rows=256),
        resolve_op("serialize", layout="columnar"),
    ], kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shard_source(n_shards, rows=100):
    for i in range(n_shards):
        yield IngestItem(gen_lineitem(rows, seed=i))


# ---------------------------------------------------------------------------
class TestCommitSequencer:
    def test_commit_blocks_until_predecessor_commits(self, store):
        store.begin_epoch(0)
        store.begin_epoch(1)   # concurrent staging is allowed now
        with store.epoch_context(1):
            store.put_block(IngestItem(np.arange(4), Granularity.BLOCK), "n0")
        done = []

        def commit1():
            store.commit_epoch(1)
            done.append(1)

        t = threading.Thread(target=commit1, daemon=True)
        t.start()
        time.sleep(0.15)
        assert done == []   # epoch 1 is held: epoch 0 still staging
        store.commit_epoch(0)
        t.join(timeout=5)
        assert done == [1]
        assert store.committed_epoch_ids() == [0, 1]

    def test_abort_of_predecessor_releases_commit(self, store):
        store.begin_epoch(0)
        store.begin_epoch(1)
        done = []

        def commit1():
            store.commit_epoch(1)
            done.append(1)

        t = threading.Thread(target=commit1, daemon=True)
        t.start()
        time.sleep(0.1)
        assert done == []
        store.abort_epoch(0)   # predecessor dies -> successor may publish
        t.join(timeout=5)
        assert done == [1]
        assert store.committed_epoch_ids() == [1]

    def test_ambiguous_put_without_context_is_refused(self, store):
        store.begin_epoch(0)
        store.begin_epoch(1)
        with pytest.raises(RuntimeError, match="epoch_context"):
            store.put_block(IngestItem(np.arange(4), Granularity.BLOCK), "n0")
        # bound writes attribute correctly
        with store.epoch_context(0):
            e0 = store.put_block(IngestItem(np.arange(4), Granularity.BLOCK), "n0")
        with store.epoch_context(1):
            e1 = store.put_block(IngestItem(np.arange(5), Granularity.BLOCK), "n1")
        assert (e0.epoch, e1.epoch) == (0, 1)
        store.abort_epoch(0)
        store.abort_epoch(1)

    def test_segment_split_metadata(self, store):
        plans = shuffled_plan(store).compile()
        assert [sp.commit_side for sp in plans] == [False, False, True]
        assert split_pipeline_segments(plans) == 2
        # single-stage upload plans have no ingest segment
        assert split_pipeline_segments(columnar_plan(store).compile()) == 0


# ---------------------------------------------------------------------------
class TestPipelinedEpochs:
    def test_pipelined_equals_sequential_output(self, tmp_path):
        rows = {}
        for mode in (True, False):
            ds = DataStore(str(tmp_path / f"s{mode}"), nodes=["n0", "n1", "n2", "n3"])
            eng = StreamingRuntimeEngine(ds, epoch_items=4, queue_capacity=8,
                                         pipelined=mode)
            rep = eng.run_stream(shuffled_plan(ds), shard_source(12, rows=100))
            assert rep.committed_epoch_ids() == [0, 1, 2]
            cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
            rows[mode] = np.sort(cols["quantity"])
            eng.close()
        np.testing.assert_array_equal(rows[True], rows[False])

    def test_plan_ships_once_not_per_epoch(self, store):
        """Persistent NodeExecutors install the plan clone once per node —
        epochs stop re-shipping plans at every barrier."""
        calls = []

        class CountingEngine(StreamingRuntimeEngine):
            def launch_remote(self, node, stage_plans):
                calls.append((node, len(stage_plans)))
                return super().launch_remote(node, stage_plans)

        eng = CountingEngine(store, epoch_items=4, queue_capacity=8)
        rep = eng.run_stream(columnar_plan(store), shard_source(12, rows=50))
        assert len(rep.epochs) == 3
        # one clone per node for the whole stream (no deaths -> no replay
        # clones), instead of one per node per epoch per _execute call
        assert len(calls) == len(store.nodes)
        eng.close()

    def test_async_shuffle_rounds_recorded(self, store):
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8)
        rep = eng.run_stream(shuffled_plan(store), shard_source(8, rows=100))
        assert sum(e.run.shuffle_async_rounds for e in rep.epochs) >= 2
        assert sum(e.run.shuffled_items for e in rep.epochs) > 0
        assert all(e.run.shuffle_spills == 0 for e in rep.epochs)
        eng.close()

    def test_oversized_shuffle_takes_spill_path(self, store):
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     shuffle_spill_bytes=1)  # everything spills
        rep = eng.run_stream(shuffled_plan(store), shard_source(8, rows=100))
        assert sum(e.run.shuffle_spills for e in rep.epochs) >= 2
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 100   # exactly once either path
        eng.close()

    def test_pipelined_node_death_keeps_epochs_contiguous(self, store):
        """Acceptance: committed epoch ids stay contiguous and in-order under
        an injected mid-epoch node death, with zero loss."""
        n_shards, rows = 16, 100
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8)
        faults = StreamFaultInjection(node_death_in_epoch={"n2": 1})
        rep = eng.run_stream(shuffled_plan(store), shard_source(n_shards, rows),
                             faults=faults)
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        assert rep.node_failures == ["n2"]
        assert rep.replayed_epochs == [1]
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == n_shards * rows
        eng.close()


# ---------------------------------------------------------------------------
class TestConcurrentReaders:
    def test_reader_only_sees_contiguous_committed_epochs(self, store):
        """A thread polling since_epoch during pipelined streaming must only
        ever observe gap-free, in-order committed epochs — including across
        an injected node death (ISSUE 2 acceptance)."""
        stop = threading.Event()
        bad: list = []
        snapshots: list = []

        def poll():
            while not stop.is_set():
                ids = store.committed_epoch_ids()
                if ids != list(range(len(ids))):
                    bad.append(("store-ids", ids))
                acc = DataAccess(store)
                seen = sorted({e.epoch for e in acc.since_epoch(-1).entries})
                if seen != list(range(len(seen))):
                    bad.append(("access-epochs", seen))
                # frontier is computed after the ids snapshot — commits may
                # land between the reads, so it can only move forward
                if acc.committed_frontier() < len(ids) - 1:
                    bad.append(("frontier", acc.committed_frontier(), ids))
                snapshots.append(len(seen))
                time.sleep(0.002)

        reader = threading.Thread(target=poll, daemon=True)
        reader.start()
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8)
        faults = StreamFaultInjection(node_death_in_epoch={"n1": 1})
        rep = eng.run_stream(shuffled_plan(store), shard_source(16, rows=100),
                             faults=faults)
        stop.set()
        reader.join(timeout=5)
        eng.close()
        assert not bad, f"non-contiguous observations: {bad[:5]}"
        assert rep.replayed_epochs == [1]
        # the reader actually watched ingestion progress mid-flight
        assert len(set(snapshots)) > 1


# ---------------------------------------------------------------------------
class TestStoreModes:
    def test_torn_journal_line_is_an_uncommitted_epoch(self, store):
        store.begin_epoch(0)
        store.put_block(IngestItem(np.arange(8), Granularity.BLOCK), "n0")
        store.commit_epoch(0)
        store.begin_epoch(1)
        store.put_block(IngestItem(np.arange(9), Granularity.BLOCK), "n1")
        store.commit_epoch(1)
        # crash mid-append: tear the journal's last line
        with open(store.epoch_journal_path) as f:
            lines = f.readlines()
        with open(store.epoch_journal_path, "w") as f:
            f.write(lines[0])
            f.write(lines[1][: len(lines[1]) // 2])
        revived = DataStore(store.root, nodes=store.nodes)
        assert revived.committed_epoch_ids() == [0]   # torn line never committed
        assert revived.gc_orphans()                   # epoch 1's block reclaimed

    def test_snapshot_commit_mode_skips_journal(self, tmp_path):
        ds = DataStore(str(tmp_path / "s"), nodes=["n0"], journal_commits=False)
        ds.begin_epoch(0)
        ds.put_block(IngestItem(np.arange(8), Granularity.BLOCK), "n0")
        ds.commit_epoch(0)
        assert not os.path.exists(ds.epoch_journal_path)
        assert DataStore(ds.root, nodes=ds.nodes).committed_epoch_ids() == [0]

    def test_compressed_store_roundtrip(self, tmp_path):
        ds = DataStore(str(tmp_path / "c"), nodes=["n0"], compress=True)
        data = np.zeros(4096, dtype=np.int64)   # very compressible
        entry = ds.put_block(IngestItem(data, Granularity.BLOCK), "n0")
        assert entry.compressed and entry.nbytes < entry.logical_nbytes()
        assert ds.verify_block(entry.block_id)  # size check uses on-disk bytes
        out = np.frombuffer(ds.read_payload(entry.block_id), dtype=np.int64)
        np.testing.assert_array_equal(out, data)

    def test_synchronous_shuffle_mode_still_exact_once(self, store):
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     pipelined=False, shuffle_synchronous=True)
        rep = eng.run_stream(shuffled_plan(store), shard_source(8, rows=100))
        assert sum(e.run.shuffle_spills for e in rep.epochs) >= 2  # sync rounds
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 100
        eng.close()


# ---------------------------------------------------------------------------
class TestGcOrphans:
    def test_crash_mid_epoch_leaves_orphans_gc_removes_them(self, store):
        # epoch 0 commits cleanly
        store.begin_epoch(0)
        store.put_block(IngestItem(np.arange(16), Granularity.BLOCK,
                                   (), {}).with_label("chunk", 0), "n0")
        store.commit_epoch(0, n_items=1)

        # epoch 1 "crashes" mid-stage: blocks on disk, never committed
        store.begin_epoch(1)
        e1 = store.put_block(IngestItem(np.arange(32), Granularity.BLOCK,
                                        (), {}).with_label("chunk", 1), "n1")
        e2 = store.put_block(IngestItem(np.arange(32), Granularity.BLOCK,
                                        (), {}).with_label("chunk", 2), "n2")
        dead_files = [os.path.join(store.root, e.path) for e in (e1, e2)]
        assert all(os.path.exists(f) for f in dead_files)

        # crash = a fresh process loads only the committed manifest
        revived = DataStore(store.root, nodes=store.nodes)
        assert revived.committed_epoch_ids() == [0]
        removed = revived.gc_orphans()
        assert sorted(removed) == sorted(
            os.path.normpath(e.path) for e in (e1, e2))
        assert not any(os.path.exists(f) for f in dead_files)
        # committed data survives the sweep, and a second sweep is a no-op
        assert revived.gc_orphans() == []
        assert len(DataAccess(revived).since_epoch(-1)) == 1
        assert revived.verify_block(next(iter(revived.entries)))

    def test_gc_keeps_blocks_of_inflight_staging_epoch(self, store):
        store.begin_epoch(0)
        e = store.put_block(IngestItem(np.arange(8), Granularity.BLOCK), "n0")
        assert store.gc_orphans() == []   # staged-in-this-process != orphan
        assert os.path.exists(os.path.join(store.root, e.path))
        store.commit_epoch(0)


# ---------------------------------------------------------------------------
class TestUnroutedItems:
    def test_stop_mid_backpressure_parks_inflight_item(self):
        q = IngestQueues(iter([IngestItem({"x": np.arange(2)}) for _ in range(5)]),
                         ["n0"], capacity=1)
        time.sleep(0.2)          # feeder: 1 queued, 1 in hand (blocked)
        assert q.produced == 2 and q.qsizes()["n0"] == 1
        q.stop()
        q.exhausted.wait(timeout=2)
        assert len(q.unrouted) == 1          # the in-flight item is recorded
        assert q.produced == q.qsizes()["n0"] + len(q.unrouted) + 0

    def test_all_nodes_dead_parks_item_instead_of_dropping(self):
        q = IngestQueues.manual(["n0", "n1"], capacity=4)
        q.mark_dead("n0")
        q.mark_dead("n1")
        item = IngestItem({"x": np.arange(2)})
        assert q.put(item) is False
        assert q.unrouted == [item]
        q.close()


# ---------------------------------------------------------------------------
class TestFeedFanout:
    def _mk(self, tmp_path, name):
        ds = DataStore(str(tmp_path / name), nodes=["n0", "n1"])
        return ds, columnar_plan(ds, name=name, epoch_items=4)

    def test_one_source_feeds_two_plans(self, tmp_path):
        dsa, pa = self._mk(tmp_path, "a")
        dsb, pb = self._mk(tmp_path, "b")
        reports = stream_ingest_multi([pa, pb], shard_source(12, rows=50),
                                      [dsa, dsb])
        assert set(reports) == {"a", "b"}
        for name, ds in (("a", dsa), ("b", dsb)):
            assert reports[name].total_items == 12
            assert reports[name].committed_epoch_ids() == [0, 1, 2]
            cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
            assert len(cols["quantity"]) == 12 * 50   # every plan sees every item

    def test_feed_language_surface(self, tmp_path):
        dsa, pa = self._mk(tmp_path, "clean")
        dsb, pb = self._mk(tmp_path, "analytics")
        feeds = parse_feed_script("FEED input INTO clean, analytics;",
                                  env={"clean": pa, "analytics": pb})
        assert len(feeds) == 1 and isinstance(feeds[0], FeedSpec)
        assert feeds[0].plan_names == ["clean", "analytics"]
        reports = stream_ingest_multi(feeds[0], shard_source(8, rows=50),
                                      [dsa, dsb])
        assert all(r.total_items == 8 for r in reports.values())

    def test_bad_feed_statements_rejected(self, tmp_path):
        ds, p = self._mk(tmp_path, "a")
        with pytest.raises(LanguageError):
            parse_feed_script("FEED input INTO missing;", env={"a": p})
        with pytest.raises(LanguageError):
            parse_feed_script("FEED input;", env={"a": p})
        with pytest.raises(LanguageError):
            parse_feed_script("SELECT * FROM input;", env={})  # no FEED at all

    def test_shared_store_is_rejected(self, tmp_path):
        ds, pa = self._mk(tmp_path, "a")
        pb = columnar_plan(ds, name="b", epoch_items=4)
        with pytest.raises(ValueError, match="own DataStore"):
            stream_ingest_multi([pa, pb], shard_source(4), [ds, ds])
