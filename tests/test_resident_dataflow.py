"""Node-resident dataflow (ISSUE 5): zero-coordinator item bytes end-to-end.

The exchange plane now covers *every* stage edge, not just shuffles: a narrow
edge keeps each node's output resident in its own ``PartitionExchange``
bucket (identity routing), cross-segment edges pin their round across
``_execute`` slices, and terminal stages reply sink counts — so item bytes
cross a coordinator pipe only for the final store-stage registration
metadata.  Covers the compiled edge taxonomy, the acceptance invariant
(``RunReport.stage_coordinator_bytes == 0`` on a >=3-stage process-backend
plan), resident-bucket recovery on node death (both backends, exactly-once,
no leaked segments or spill files), the batch cohort-replay fix for
post-shuffle deaths (injected + real SIGTERM), and resident-spill GC.
"""
import glob
import os
import time

import numpy as np
import pytest

from repro.core import (DataAccess, DataStore, FaultInjection, IngestPlan,
                        IngestionOptimizer, RuntimeEngine,
                        StreamFaultInjection, StreamingRuntimeEngine,
                        annotate_edges, chain_stage, create_stage,
                        resident_file_name, resolve_op)
from repro.core.exchange import is_exchange_file, write_partition_file
from repro.core.items import IngestItem
from repro.data.generators import gen_lineitem


def narrow_plan(ds):
    """Three stages chained by narrow edges only: parse -> chunk+serialize ->
    upload.  No shuffle key anywhere — every boundary is identity-routed."""
    p = IngestPlan("narrow3")
    s1 = p.add_statement([resolve_op("identity_parser")], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar")],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shuffled_plan(ds):
    """Shuffle at stage a, consumed by b, stored by c (all ops picklable)."""
    p = IngestPlan("shuf")
    s1 = p.add_statement([
        resolve_op("identity_parser"),
        resolve_op("partition", scheme="hash", key="orderkey",
                   num_partitions=4),
        resolve_op("map", fn="repro.core.ops_select:identity_columns",
                   shuffle_by="partition"),
    ], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar")],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shard_source(n_shards, rows=100, delay_s=0.0):
    for i in range(n_shards):
        if delay_s:
            time.sleep(delay_s)
        yield IngestItem(gen_lineitem(rows, seed=i))


def shards(n_shards, rows=100):
    return list(shard_source(n_shards, rows))


def agg(rep, field):
    return sum(getattr(e.run, field) for e in rep.epochs)


def shm_segments():
    """Live shared-memory segments on this host (leak detection)."""
    return set(glob.glob("/dev/shm/psm_*"))


# ---------------------------------------------------------------------------
class TestEdgeTaxonomy:
    def test_compile_marks_narrow_shuffle_cross_segment(self, store):
        plans = shuffled_plan(store).compile()
        # a shuffles into b; b's edge to the commit-side stage c crosses the
        # ingest/store segment boundary
        assert plans[0].edge_kinds == {"b": "shuffle"}
        assert plans[1].edge_kinds == {"c": "cross-segment"}
        assert plans[2].edge_kinds == {}
        narrow = narrow_plan(store).compile()
        assert narrow[0].edge_kinds == {"b": "narrow"}
        assert narrow[1].edge_kinds == {"c": "cross-segment"}

    def test_optimizer_recomputes_and_clone_preserves(self, store):
        opt = IngestionOptimizer().optimize(shuffled_plan(store).compile())
        assert opt[0].edge_kinds == {"b": "shuffle"}
        assert opt[0].clone().edge_kinds == {"b": "shuffle"}
        # annotate_edges is idempotent over rewritten plans
        assert annotate_edges(opt)[1].edge_kinds == {"c": "cross-segment"}

    def test_single_segment_shuffle_edge(self, store):
        """With the upload fused into the consuming stage there is no
        segment boundary — the edge is plain shuffle."""
        p = IngestPlan("one")
        s1 = p.add_statement([
            resolve_op("identity_parser"),
            resolve_op("partition", scheme="hash", key="orderkey",
                       num_partitions=4),
            resolve_op("map", fn="repro.core.ops_select:identity_columns",
                       shuffle_by="partition"),
        ], kind="select")
        s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                              resolve_op("serialize", layout="columnar"),
                              resolve_op("upload", store=store)],
                             kind="store", inputs=[s1])
        create_stage(p, using=[s1], name="a")
        chain_stage(p, to=["a"], using=[s2], name="b")
        plans = p.compile()
        # stage a IS before the split (b is the first commit-side stage),
        # so a->b crosses the segment boundary
        assert plans[0].edge_kinds == {"b": "cross-segment"}


# ---------------------------------------------------------------------------
class TestZeroStageCoordinatorBytes:
    """Acceptance: on a >=3-stage non-shuffle plan, zero item bytes cross
    the coordinator pipes at stage boundaries — narrow edges stay resident,
    the terminal stage replies a sink count."""

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_streaming_narrow_plan(self, tmp_path, backend):
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2", "n3"])
        eng = StreamingRuntimeEngine(ds, epoch_items=4, queue_capacity=8,
                                     backend=backend)
        rep = eng.run_stream(narrow_plan(ds), shard_source(8, rows=100))
        eng.close()
        assert agg(rep, "stage_coordinator_bytes") == 0
        assert agg(rep, "stage_resident_bytes") > 0
        assert agg(rep, "stage_exchange_rounds") >= len(rep.epochs)
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 100
        assert not os.listdir(ds.dfs_dir)

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_batch_narrow_plan(self, tmp_path, backend):
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1"])
        with RuntimeEngine(ds, backend=backend) as eng:
            rep = eng.run(narrow_plan(ds), shards(6, rows=80))
        assert rep.stage_coordinator_bytes == 0
        assert rep.stage_exchange_rounds == 2          # a->b, b->c
        assert rep.stage_items["a"] == 6 and rep.stage_items["c"] == 6
        cols = DataAccess(ds).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 6 * 80

    def test_shuffle_plan_is_zero_on_both_planes(self, store):
        """A shuffle plan now keeps BOTH the shuffle edge (PR 4) and every
        narrow/cross-segment edge (ISSUE 5) off the coordinator."""
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     backend="process")
        rep = eng.run_stream(shuffled_plan(store), shard_source(8, rows=100))
        eng.close()
        assert agg(rep, "shuffle_coordinator_bytes") == 0
        assert agg(rep, "stage_coordinator_bytes") == 0
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 100

    def test_synchronous_mode_still_counts_stage_bytes(self, store):
        """The legacy mode remains the counted coordinator data path for
        stage boundaries too — the counter is live, not vacuous."""
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     pipelined=False, shuffle_synchronous=True)
        rep = eng.run_stream(narrow_plan(store), shard_source(4, rows=100))
        eng.close()
        assert agg(rep, "stage_coordinator_bytes") > 0
        assert agg(rep, "stage_exchange_rounds") == 0

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_oversized_resident_buckets_spill(self, tmp_path, backend):
        """A narrow output past the per-edge share spills to a resident_*
        DFS file — consumed on read, still zero coordinator bytes."""
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1"])
        eng = StreamingRuntimeEngine(ds, epoch_items=4, queue_capacity=8,
                                     backend=backend, shuffle_spill_bytes=1)
        rep = eng.run_stream(narrow_plan(ds), shard_source(8, rows=100))
        eng.close()
        assert agg(rep, "resident_spills") >= 1
        assert agg(rep, "stage_coordinator_bytes") == 0
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 100
        assert not os.listdir(ds.dfs_dir)   # consumed on read


# ---------------------------------------------------------------------------
class TestResidentRecovery:
    """Satellite: node death between two non-shuffle stages replays the
    epoch exactly-once on both backends, with no leaked shm segments or
    spill files."""

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_death_between_narrow_stages_replays_exactly_once(self, tmp_path,
                                                              backend):
        before = shm_segments()
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2", "n3"])
        eng = StreamingRuntimeEngine(ds, epoch_items=4, queue_capacity=8,
                                     backend=backend)
        # the injected death fires after the epoch's first stage — between
        # narrow stages a and b, while a's output sits in resident buckets
        faults = StreamFaultInjection(node_death_in_epoch={"n2": 1})
        rep = eng.run_stream(narrow_plan(ds), shard_source(16, rows=100),
                             faults=faults)
        eng.close()
        assert rep.committed_epoch_ids() == [0, 1, 2, 3]
        assert rep.replayed_epochs == [1]
        assert agg(rep, "stage_coordinator_bytes") == 0
        cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 16 * 100   # no loss, no duplication
        assert not os.listdir(ds.dfs_dir)
        assert ds.gc_orphans() == []
        assert shm_segments() - before == set()    # no leaked segments

    def test_worker_sigterm_between_narrow_stages(self, store):
        """Real SIGTERM while narrow resident buckets are live: the epoch
        invalidates its rounds everywhere and replays exactly-once."""
        before = shm_segments()
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     backend="process")
        eng.prewarm_executors()
        killed = []

        def kill_mid_round(rnd, src):
            # first narrow manifest of epoch >= 1: resident buckets exist
            if rnd.epoch >= 1 and rnd.key is None and not killed:
                victim = next(t for t in rnd.targets if t != src)
                killed.append(victim)
                eng.executor(victim).kill()

        eng.shuffle.test_on_manifest = kill_mid_round
        rep = eng.run_stream(narrow_plan(store),
                             shard_source(16, rows=100, delay_s=0.02))
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        assert killed and killed[0] in rep.node_failures
        assert rep.replayed_epochs
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 16 * 100
        eng.close()
        assert not os.listdir(store.dfs_dir)
        assert store.gc_orphans() == []
        assert shm_segments() - before == set()

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_dead_nodes_spilled_resident_bucket_is_reclaimed(self, tmp_path,
                                                             backend):
        """A node dying with a *spilled* resident bucket (resident_* file on
        the DFS) must not leak it past the round: finish_round reclaims the
        unfetched file even though the owning worker's bucket died with it."""
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2"])
        faults = FaultInjection(node_death_after_stage={"n2": "a"})
        with RuntimeEngine(ds, backend=backend, shuffle_spill_bytes=1) as eng:
            rep = eng.run(narrow_plan(ds), shards(6, rows=100), faults=faults)
        assert rep.node_failures == ["n2"]
        assert rep.resident_spills >= 1
        cols = DataAccess(ds).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 6 * 100
        assert not os.listdir(ds.dfs_dir)   # no leaked resident_* files
        assert ds.gc_orphans() == []

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_batch_death_between_narrow_stages_is_exact(self, tmp_path,
                                                        backend):
        """Batch (reassign) mode: narrow lineage is self-contained, so the
        dead node's shards replay exactly — no cohort escalation needed."""
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2"])
        faults = FaultInjection(node_death_after_stage={"n2": "a"})
        with RuntimeEngine(ds, backend=backend) as eng:
            rep = eng.run(narrow_plan(ds), shards(6, rows=100), faults=faults)
        assert rep.node_failures == ["n2"]
        assert rep.cohort_replays == 0
        cols = DataAccess(ds).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 6 * 100
        assert not os.listdir(ds.dfs_dir)


# ---------------------------------------------------------------------------
class TestBatchCohortReplay:
    """Satellite: the pre-existing batch shuffle replay hole — a node dying
    *after* a shuffle-consuming stage — now falls back to whole-run cohort
    replay (the run is one epoch), restoring exactly-once."""

    @pytest.mark.parametrize("backend", ["thread", "process"])
    def test_post_shuffle_death_triggers_cohort_replay(self, tmp_path,
                                                       backend):
        ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2"])
        # death after stage b — b consumed shuffled groups, so n2's state
        # mixed other nodes' lineages (the ROADMAP hole)
        faults = FaultInjection(node_death_after_stage={"n2": "b"})
        with RuntimeEngine(ds, backend=backend) as eng:
            rep = eng.run(shuffled_plan(ds), shards(6, rows=100),
                          faults=faults)
        assert rep.node_failures == ["n2"]
        assert rep.cohort_replays == 1
        cols = DataAccess(ds).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 6 * 100   # no loss, no double count
        assert not os.listdir(ds.dfs_dir)
        assert ds.gc_orphans() == []              # aborted attempt rolled back

    def test_post_shuffle_sigterm_cohort_replay(self, store):
        """Regression (satellite): a real SIGTERM after the shuffle-consuming
        stage in batch mode — detected at the next stage's submission — must
        cohort-replay, not double-count via shard reassignment."""
        eng = RuntimeEngine(store, backend="process")
        eng.prewarm_executors()
        fired = []

        def kill_after_consume(rnd, src):
            # the b->c narrow manifest means stage b (the shuffle consumer)
            # finished on src: SIGTERM it with its processed groups on board
            if rnd.key is None and rnd.stage == "b" and not fired:
                fired.append(src)
                eng.executor(src).kill()
                time.sleep(0.4)   # let the EOF sentinel land

        eng.shuffle.test_on_manifest = kill_after_consume
        rep = eng.run(shuffled_plan(store), shards(6, rows=100))
        assert fired and fired[0] in rep.node_failures
        assert rep.cohort_replays >= 1
        cols = DataAccess(store).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 6 * 100
        eng.close()
        assert not os.listdir(store.dfs_dir)
        assert store.gc_orphans() == []

    def test_pre_consumer_death_keeps_cheap_reassignment(self, store):
        """Death before any shuffle consumer ran still takes the exact
        shard-reassignment path — cohort replay is the escalation, not the
        default."""
        faults = FaultInjection(node_death_after_stage={"n3": "a"})
        with RuntimeEngine(store) as eng:
            rep = eng.run(shuffled_plan(store), shards(6, rows=100),
                          faults=faults)
        assert rep.cohort_replays == 0
        assert rep.node_failures == ["n3"]
        cols = DataAccess(store).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 6 * 100


# ---------------------------------------------------------------------------
class TestResidentSpillGC:
    """Satellite: ``DataStore.gc_orphans`` reclaims resident-bucket spill
    files a crash left behind, while sparing leased (live-round) paths."""

    def test_gc_reclaims_crashed_resident_spills(self, store):
        dead = os.path.join(store.dfs_dir, resident_file_name(3, 7, "n0"))
        write_partition_file(dead, [IngestItem({"x": np.arange(4)})])
        live = os.path.join(store.dfs_dir, resident_file_name(4, 8, "n1"))
        write_partition_file(live, [IngestItem({"x": np.arange(4)})])
        torn = os.path.join(store.dfs_dir,
                            resident_file_name(5, 9, "n2") + ".tmp")
        with open(torn, "wb") as f:
            f.write(b"half-written")
        assert is_exchange_file(os.path.basename(dead))
        assert is_exchange_file(os.path.basename(torn))
        # a crash: a fresh DataStore on the same root holds no leases
        fresh = DataStore(store.root, nodes=store.nodes)
        fresh.lease_exchange_path(live)
        removed = fresh.gc_orphans()
        assert os.path.join("dfs", os.path.basename(dead)) in removed
        assert os.path.join("dfs", os.path.basename(torn)) in removed
        assert not os.path.exists(dead) and not os.path.exists(torn)
        assert os.path.exists(live)            # leased: spared
        fresh.release_exchange_path(live)
        assert os.path.join("dfs", os.path.basename(live)) in fresh.gc_orphans()

    def test_crash_restart_end_to_end(self, tmp_path):
        """Fabricate what a crash mid-slice leaves (resident spills of a
        pinned round nobody will ever consume) and assert a restarted
        store reclaims them."""
        ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
        for node in ("n0", "n1"):
            write_partition_file(
                os.path.join(ds.dfs_dir, resident_file_name(2, 5, node)),
                [IngestItem({"x": np.arange(16)})])
        restarted = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
        removed = restarted.gc_orphans()
        assert len([r for r in removed if "resident_" in r]) == 2
        assert not any(f.startswith("resident_")
                       for f in os.listdir(restarted.dfs_dir))


# ---------------------------------------------------------------------------
class TestPerfGateResidentMetric:
    def test_resident_metric_is_gated_by_default(self, tmp_path):
        import json
        from benchmarks.perf_gate import DEFAULT_METRICS, main
        assert "resident_rows_per_s" in DEFAULT_METRICS
        traj = str(tmp_path / "t.json")
        with open(traj, "w") as f:
            json.dump([
                {"scale": 1000, "pipelined_rows_per_s": 100.0,
                 "shuffle_rows_per_s": 100.0, "resident_rows_per_s": 100.0},
                {"scale": 1000, "pipelined_rows_per_s": 100.0,
                 "shuffle_rows_per_s": 100.0, "resident_rows_per_s": 50.0},
            ], f)
        assert main(["--file", traj]) == 1      # resident regression gates
        # histories that predate the metric skip cleanly
        with open(traj, "w") as f:
            json.dump([
                {"scale": 1000, "pipelined_rows_per_s": 100.0},
                {"scale": 1000, "pipelined_rows_per_s": 100.0,
                 "resident_rows_per_s": 50.0},
            ], f)
        assert main(["--file", traj]) == 0
