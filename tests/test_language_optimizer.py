"""The declarative language (DSL + SQL-ish text) and the rule optimizer."""
import numpy as np
import pytest

from repro.core import (DataStore, IngestionOptimizer, IngestPlan, chain_stage,
                        create_stage, format_, parse_ingestion_script, select)
from repro.core import store as store_stmt
from repro.core.items import Granularity, IngestItem
from repro.core.operators import MaterializeOp
from repro.core.runtime import RuntimeEngine


def lineitem_items(n=2000, shards=4):
    from repro.data.generators import as_file_items, gen_lineitem
    return as_file_items(gen_lineitem(n), shards)


class TestDSL:
    def test_select_format_store_compile(self, store):
        p = IngestPlan("t")
        s1 = select(p, where=("quantity", ">", 10), replicate=2)
        s2 = format_(p, s1, chunk={"target_rows": 256}, serialize="columnar")
        s3 = store_stmt(p, s2, locate="roundrobin", upload=store)
        create_stage(p, using=[s1, s2, s3])
        sps = p.compile()
        assert len(sps) == 1
        names = [type(o).__name__ for o in sps[0].ops]
        assert "FilterOp" in names and "SerializeOp" in names

    def test_statement_granularity_validation(self):
        p = IngestPlan("bad")
        # ORDER (chunk-granularity) after serialize (block) must fail validation
        s1 = select(p)
        s2 = format_(p, s1, serialize="columnar")
        s3 = format_(p, s2, order={"key": "a"})
        create_stage(p, using=[s1, s2, s3])
        with pytest.raises(Exception):
            p.compile()

    def test_stage_routing_predicates(self, store):
        p = IngestPlan("routes")
        s1 = select(p, replicate=2)
        s2 = format_(p, s1, serialize="columnar")
        s3 = format_(p, s1, serialize="row")
        st = store_stmt(p, s2, s3, upload=store)
        create_stage(p, using=[s1], name="a")
        chain_stage(p, to=["a"], using=[s2], where={"replicate": 1}, name="b")
        chain_stage(p, to=["a"], using=[s3], where={"replicate": 2}, name="c")
        chain_stage(p, to=["b", "c"], using=[st], name="d")
        sps = p.compile()
        assert [sp.name for sp in sps] == ["a", "b", "c", "d"]
        assert sps[1].predicates == {"replicate": 1}


class TestTextFrontend:
    def test_paper_syntax_round_trip(self, store):
        script = """
        s1 = SELECT * FROM input USING parser REPLICATE BY 2;
        s2 = FORMAT s1 CHUNK BY 1000 SERIALIZE AS columnar;
        s3 = STORE s2 LOCATE USING roundrobin UPLOAD TO target;
        CREATE STAGE a USING s1;
        CHAIN STAGE b TO a USING s2,s3 WHERE l_replicate=1;
        """
        plan = parse_ingestion_script(script, env={"target": store})
        sps = plan.compile()
        assert [sp.name for sp in sps] == ["a", "b"]
        assert sps[1].predicates == {"replicate": 1}

    def test_size_suffixes(self, store):
        script = """
        s1 = SELECT * FROM input;
        s2 = FORMAT s1 CHUNK BY 100mb;
        CREATE STAGE a USING s1,s2;
        """
        plan = parse_ingestion_script(script, env={"target": store})
        ops = plan.compile()[0].ops
        chunk = [o for o in ops if o.name == "chunk"][0]
        assert chunk.params.get("target_bytes") == 100 << 20


class TestOptimizer:
    def test_reorder_pushes_replicate_late(self):
        p = IngestPlan("r")
        s1 = select(p, replicate=3, where=("quantity", ">", 25))
        create_stage(p, using=[s1])
        sps = IngestionOptimizer().optimize(p.compile())
        ops = [o for o in sps[0].ops if not isinstance(o, MaterializeOp)]
        kinds = [o.name for o in ops]
        # replicate (expander) must come after filter (reducer)
        assert kinds.index("filter") < kinds.index("replicate")

    def test_reordered_plan_is_equivalent(self, tmp_path):
        items = lineitem_items()
        totals = []
        for optimize in (False, True):
            ds = DataStore(str(tmp_path / f"s{optimize}"), nodes=["n0", "n1"])
            p = IngestPlan("eq")
            s1 = select(p, replicate=2, where=("quantity", ">", 25))
            s2 = format_(p, s1, chunk={"target_rows": 128}, serialize="columnar")
            s3 = store_stmt(p, s2, upload=ds)
            create_stage(p, using=[s1, s2, s3])
            eng = RuntimeEngine(ds)
            eng.run(p, [IngestItem(dict(i.data), i.granularity)
                        for i in items], optimize=optimize)
            totals.append(sum(ds.read_item(e.block_id).nrows()
                              for e in ds.blocks()))
        assert totals[0] == totals[1] > 0

    def test_pipeline_blocks_split_at_granularity_change(self):
        p = IngestPlan("pipe")
        s1 = select(p, where=("quantity", ">", 10))
        s2 = format_(p, s1, chunk={"target_rows": 64}, serialize="columnar")
        create_stage(p, using=[s1, s2])
        sps = IngestionOptimizer().optimize(p.compile())
        blocks = sps[0].pipeline_blocks
        assert len(blocks) >= 2  # serialize (CHUNK->BLOCK) forces a barrier
        flat = [i for b in blocks for i in b]
        assert flat == sorted(flat)

    def test_rules_fire_until_fixpoint(self):
        p = IngestPlan("fx")
        s1 = select(p, replicate=2)
        s2 = format_(p, s1, chunk={"target_rows": 64})
        create_stage(p, using=[s1, s2])
        opt = IngestionOptimizer()
        once = opt.optimize(p.compile())
        twice = opt.optimize(once)
        assert [type(o).__name__ for o in once[0].ops] == \
               [type(o).__name__ for o in twice[0].ops]
