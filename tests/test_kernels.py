"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import flash_attention, gf256_matmul, pack_tokens


class TestGF256Matmul:
    @pytest.mark.parametrize("P,K,N", [(1, 2, 256), (3, 10, 5000),
                                       (4, 8, 2048), (2, 5, 131)])
    def test_matches_table_oracle(self, P, K, N, rng):
        code = rng.integers(0, 256, (P, K)).astype(np.uint8)
        data = rng.integers(0, 256, (K, N)).astype(np.uint8)
        out = np.asarray(gf256_matmul(jnp.asarray(code), jnp.asarray(data),
                                      block_n=1024))
        assert np.array_equal(out, ref.gf256_matmul_ref(code, data))

    def test_identity_code_matrix(self, rng):
        K, N = 4, 512
        code = np.eye(K, dtype=np.uint8)
        data = rng.integers(0, 256, (K, N)).astype(np.uint8)
        out = np.asarray(gf256_matmul(jnp.asarray(code), jnp.asarray(data)))
        assert np.array_equal(out, data)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,KV,d", [
        (1, 128, 2, 2, 64),    # MHA
        (2, 256, 4, 2, 64),    # GQA 2:1
        (1, 512, 8, 1, 128),   # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_dense_oracle(self, B, S, H, KV, d, dtype, rng):
        q = jnp.asarray(rng.normal(size=(B, S, H, d)), dtype)
        k = jnp.asarray(rng.normal(size=(B, S, KV, d)), dtype)
        v = jnp.asarray(rng.normal(size=(B, S, KV, d)), dtype)
        out = flash_attention(q, k, v, bq=128, bk=64)
        exp = ref.flash_attention_ref(q, k, v)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32), atol=tol)

    def test_non_causal(self, rng):
        q = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
        out = flash_attention(q, k, v, causal=False, bq=64, bk=64)
        exp = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)

    def test_matches_model_attention(self, rng):
        """The kernel is a drop-in for models/attention.attention_chunked."""
        from repro.models.attention import attention_chunked
        B, S, H, d = 1, 256, 4, 64
        q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
        seg = jnp.ones((B, S), jnp.int32)
        out_model = attention_chunked(q, k, v, pos, pos, seg, seg, chunk=64)
        out_kernel = flash_attention(q, k, v, bq=64, bk=64)
        np.testing.assert_allclose(np.asarray(out_model), np.asarray(out_kernel),
                                   atol=3e-5)


class TestPackTokens:
    @pytest.mark.parametrize("seq_len", [64, 128, 1024])
    def test_matches_oracle(self, seq_len, rng):
        T = 4000
        flat = rng.integers(1, 1000, T).astype(np.int32)
        starts, lens, cur = [], [], 0
        while cur < T - seq_len:
            ln = int(rng.integers(1, seq_len + 1))
            starts.append(cur)
            lens.append(ln)
            cur += ln
        starts, lens = np.array(starts, np.int32), np.array(lens, np.int32)
        t, s, p = pack_tokens(jnp.asarray(flat), jnp.asarray(starts),
                              jnp.asarray(lens), seq_len)
        te, se, pe = ref.pack_tokens_ref(flat, starts, lens, seq_len)
        assert np.array_equal(np.asarray(t), te)
        assert np.array_equal(np.asarray(s), se)
        assert np.array_equal(np.asarray(p), pe)
