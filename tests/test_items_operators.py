"""Unit tests: ingest data items, labels/lineage, the operator iterator API."""
import numpy as np
import pytest

from repro.core import IngestOp, Label, OperatorFailure, registered_ops, resolve_op
from repro.core.items import Granularity, IngestItem, concat_columns, matches, num_rows
from repro.core.operators import MaterializeOp, PassThroughOp


def make_item(n=10, gran=Granularity.CHUNK):
    return IngestItem({"a": np.arange(n), "b": np.ones(n)}, gran)


def item_cols(it):
    return it.data


class TestItems:
    def test_labels_accumulate_lineage(self):
        it = make_item().with_label("parser", 0).with_label("replicate", 2)
        assert [l.op for l in it.labels] == ["parser", "replicate"]
        assert matches(it, {"replicate": 2})
        assert not matches(it, {"replicate": 1})

    def test_lineage_filename_encodes_labels_in_order(self):
        it = make_item().with_label("parser", 3).with_label("serialize", "pax")
        name = it.lineage_name()
        assert name.index("parser") < name.index("serialize")
        assert "pax" in name

    def test_predicate_callable(self):
        it = make_item().with_label("parser", 7)
        assert matches(it, {"parser": lambda v: v > 5})
        assert not matches(it, {"parser": lambda v: v > 9})

    def test_concat_and_rows(self):
        a, b = make_item(4), make_item(6)
        cols = concat_columns([a.data, b.data])
        assert num_rows(cols) == 10

    def test_record_granularity_is_chunk_of_one(self):
        it = make_item(1, Granularity.RECORD)
        assert it.nrows() == 1

    def test_checksum_tracks_content(self):
        a, b = make_item(5), make_item(5)
        assert a.checksum() == b.checksum()
        c = IngestItem({"a": np.arange(5) + 1, "b": np.ones(5)},
                       Granularity.CHUNK)
        assert a.checksum() != c.checksum()


class TestOperatorAPI:
    def test_iterator_protocol(self):
        from dataclasses import replace

        class Doubler(IngestOp):
            name = "double"

            def process(self, item):
                yield replace(item, data={k: v * 2 for k, v in
                                          item.data.items()}).with_label(
                    self.name, 1)

        op = Doubler()
        op.initialize()
        op.setInput([make_item(3)])
        outs = []
        while op.hasNext():
            outs.append(op.next())
        op.finalize()
        assert len(outs) == 1
        assert outs[0].data["a"].tolist() == [0, 2, 4]
        assert op._finalized_ok

    def test_registry_resolves_builtins(self):
        names = registered_ops()
        for required in ("parser", "filter", "project", "replicate",
                         "partition", "chunk", "order", "serialize",
                         "locate", "upload", "erasure", "pack"):
            assert required in names, required
        op = resolve_op("filter", predicate=("a", ">", 2))
        assert isinstance(op, IngestOp)

    def test_passthrough_labels_failure(self):
        op = PassThroughOp(replaces="broken")
        outs = op.run([make_item(2)])
        assert outs[0].labels[-1].value == -1  # paper: dummy labels items -1

    def test_parallel_mode_equals_serial(self):
        from repro.core.ops_format import SerializeOp
        items = [make_item(50) for _ in range(8)]
        ser = SerializeOp(layout="columnar")
        ser.mode = ser.mode.__class__.SERIAL
        out_serial = {o.labels[-1].value if o.labels else i
                      for i, o in enumerate(ser.clone().run(list(items)))}
        par = SerializeOp(layout="columnar")
        assert par.cpu_heavy  # serialize defaults to parallel (paper Sec VI-A)
        out_par = {o.labels[-1].value if o.labels else i
                   for i, o in enumerate(par.run(list(items)))}
        assert len(out_serial) == len(out_par)
