"""Per-arch smoke tests: every assigned architecture instantiates a REDUCED
same-family config and runs one forward + one train step on CPU, asserting
output shapes and finiteness.  Full configs are exercised by the dry-run only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models.model import cache_defs, decode_step, forward, model_defs, prefill
from repro.models.params import abstract_params, init_params
from repro.training.optim import make_optimizer
from repro.training.steps import make_train_step

B, S = 2, 64

# tier-1 compiles one representative arch; the full sweep is the slow tier
# (each arch pays a multi-second JAX compile on CPU)
FAST_ARCHS = ("smollm-135m",)
ARCH_PARAMS = [a if a in FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
               for a in ARCHS]


def make_batch(cfg, rng):
    toks = rng.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {
        "tokens": jnp.asarray(toks),
        "labels": jnp.asarray(np.roll(toks, -1, axis=1)),
        "segments": jnp.ones((B, S), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32),
    }
    if "cross" in cfg.pattern + cfg.remainder:
        batch["encoder_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.cross_attn_kv_len, cfg.d_model)),
            cfg.activation_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch, rng):
        cfg = get_smoke(arch)
        params = init_params(jax.random.PRNGKey(0), model_defs(cfg))
        batch = make_batch(cfg, rng)
        h, aux = forward(cfg, params, batch)
        assert h.shape == (B, S, cfg.d_model)
        assert np.isfinite(np.asarray(h, np.float32)).all(), f"{arch}: NaN fwd"

        init_opt, _, _ = make_optimizer(cfg.optimizer)
        step = jax.jit(make_train_step(cfg, loss_chunk=32))
        p2, o2, m = step(params, init_opt(params), batch)
        assert np.isfinite(float(m["loss"])), f"{arch}: NaN loss"
        # params actually moved
        moved = jax.tree.reduce(
            lambda a, b: a or b,
            jax.tree.map(lambda x, y: bool(jnp.any(x != y)), params, p2))
        assert moved

    def test_decode_matches_forward(self, arch, rng):
        """Prefill+decode logits == full-forward logits (cache correctness)."""
        cfg = get_smoke(arch)
        if cfg.param_dtype != "float32":
            cfg = cfg.replace(dtype="float32", param_dtype="float32")
        params = init_params(jax.random.PRNGKey(0), model_defs(cfg))
        batch = make_batch(cfg, rng)
        from repro.models.layers import unembed
        h, _ = forward(cfg, params, batch)
        full_logits = unembed(params["embed"], h[:, -1:], cfg)

        pre = {k: (v[:, :S - 1] if v.shape[:2] == (B, S) else v)
               for k, v in batch.items()}
        _, cache = prefill(cfg, params, pre, max_len=S + 8)
        dec_logits, _ = decode_step(cfg, params, cache,
                                    batch["tokens"][:, S - 1:S],
                                    jnp.asarray(S - 1, jnp.int32))
        np.testing.assert_allclose(np.asarray(full_logits, np.float32),
                                   np.asarray(dec_logits, np.float32),
                                   atol=5e-2, rtol=1e-3)


def test_full_configs_match_published_sizes():
    """Analytic param counts stay within 10% of the published model sizes."""
    expected = {
        "mamba2-2.7b": 2.7e9, "llama-3.2-vision-90b": 88e9, "gemma-7b": 8.5e9,
        "glm4-9b": 9.4e9, "internlm2-20b": 20e9, "smollm-135m": 135e6,
        "recurrentgemma-2b": 2.7e9, "kimi-k2-1t-a32b": 1.04e12,
        "mixtral-8x22b": 141e9, "musicgen-medium": 1.5e9,
    }
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.12, f"{arch}: {got/1e9:.2f}B vs {n/1e9:.2f}B"


def test_moe_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    assert 30e9 < kimi.active_param_count() < 40e9
    mixtral = get_config("mixtral-8x22b")
    assert 35e9 < mixtral.active_param_count() < 45e9
