"""musicgen ingest path: delay-pattern transform inside an ingestion plan."""
import numpy as np

from repro.core import DataAccess, DataStore, IngestPlan, create_stage, format_, ingest, select
from repro.core import store as store_stmt
from repro.core.items import Granularity, IngestItem
from repro.data.audio import (DelayPatternOp, apply_delay_pattern,
                              gen_encodec_clips, undo_delay_pattern)


def test_delay_pattern_roundtrip(rng):
    codes = rng.integers(0, 2048, (4, 100)).astype(np.int32)
    assert (undo_delay_pattern(apply_delay_pattern(codes)) == codes).all()


def test_delay_shifts_each_codebook(rng):
    codes = rng.integers(1, 2048, (3, 10)).astype(np.int32)
    d = apply_delay_pattern(codes, pad_id=0)
    assert d.shape == (3, 12)
    assert d[1, 0] == 0 and d[2, 0] == 0 and d[2, 1] == 0  # leading pads
    assert (d[0, :10] == codes[0]).all()


def test_musicgen_ingest_plan_end_to_end(tmp_path):
    """EnCodec clips -> delay-pattern -> pack -> packed blocks a feeder can
    train the musicgen backbone on."""
    ds = DataStore(str(tmp_path / "s"), nodes=["n0", "n1"])
    clips = gen_encodec_clips(40, n_codebooks=4)
    items = [IngestItem(clips, Granularity.CHUNK)]

    p = IngestPlan("musicgen")
    s1 = select(p, parser=None)
    s2 = p.add_statement([DelayPatternOp(codebook_size=2048)], kind="format",
                         inputs=[s1])
    s3 = format_(p, s2, pack={"seq_len": 512, "rows_per_block": 8},
                 serialize="packed")
    s4 = store_stmt(p, s3, upload=ds)
    create_stage(p, using=[s1, s2, s3, s4], name="main")
    ingest(p, items, ds)

    cols = DataAccess(ds).filter_replica("serialize", "packed").read_all(
        projection=["tokens", "segment_ids"])
    assert cols["tokens"].shape[1] == 512
    # token conservation: every delayed+flattened token landed in a row
    expect = sum((c.shape[1] + c.shape[0] - 1) * c.shape[0]
                 for c in clips["codes"])
    assert int((cols["segment_ids"] > 0).sum()) == expect
