"""Streaming micro-batch runtime: backpressure, epoch commit/replay,
epoch-aware access, language surface, and live-store tailing."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (DataAccess, DataStore, IngestPlan, IngestQueues,
                        StreamFaultInjection, StreamingRuntimeEngine,
                        create_stage, format_, parse_ingestion_script, select,
                        stream_ingest, with_epochs)
from repro.core import store as store_stmt
from repro.core.items import Granularity, IngestItem
from repro.data.generators import gen_lineitem, gen_token_documents


def columnar_plan(ds, *, epoch_items=None):
    p = IngestPlan("stream")
    s1 = select(p)
    s2 = format_(p, s1, chunk={"target_rows": 256}, serialize="columnar")
    s3 = store_stmt(p, s2, locate="roundrobin",
                    locate_args={"num_locations": len(ds.nodes)}, upload=ds)
    create_stage(p, using=[s1, s2, s3], name="main")
    if epoch_items is not None:
        with_epochs(p, items=epoch_items)
    return p


def shard_source(n_shards, rows=100):
    """Unbounded-style source: items materialize lazily, one per pull."""
    for i in range(n_shards):
        yield IngestItem(gen_lineitem(rows, seed=i))


class TestBackpressure:
    def test_producer_blocks_at_capacity(self):
        pulled = []

        def source():
            for i in range(1000):
                pulled.append(i)
                yield IngestItem({"x": np.arange(4)})

        q = IngestQueues(source(), ["n0"], capacity=4)
        time.sleep(0.3)  # give the feeder every chance to overrun
        # bounded: capacity in the queue + at most 1 item in the feeder's hand
        assert len(pulled) <= 5
        assert q.qsizes()["n0"] == 4

        # draining an epoch releases the producer for exactly that much more
        batch = q.cut_epoch(max_items=4)
        assert sum(len(v) for v in batch.values()) == 4
        time.sleep(0.3)
        assert 5 <= len(pulled) <= 9
        q.stop()

    def test_queue_memory_stays_bounded_during_run(self, store):
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=2)
        rep = eng.run_stream(columnar_plan(store), shard_source(12, rows=50))
        assert rep.total_items == 12
        assert len(rep.epochs) == 3


class TestEpochCommit:
    def test_epochs_commit_exactly_once(self, store):
        rep = stream_ingest(columnar_plan(store, epoch_items=4),
                            shard_source(12), store)
        assert rep.committed_epoch_ids() == [0, 1, 2]
        assert store.committed_epoch_ids() == [0, 1, 2]
        # exactly-once guards
        with pytest.raises(ValueError):
            store.begin_epoch(1)
        with pytest.raises(ValueError):
            store.commit_epoch(1)

    def test_abort_rolls_back_staged_blocks(self, store):
        store.begin_epoch(0)
        it = IngestItem(np.arange(64, dtype=np.int32), Granularity.BLOCK)
        entry = store.put_block(it, "n0")
        full = os.path.join(store.root, entry.path)
        assert os.path.exists(full) and entry.epoch == 0
        assert store.abort_epoch(0) == 1
        assert not os.path.exists(full)
        assert entry.block_id not in store.entries
        # the id is free again: the epoch never committed
        store.begin_epoch(0)
        store.commit_epoch(0)

    def test_uncommitted_epoch_invisible_midflight(self, store):
        """since_epoch sees exactly the committed epochs while an epoch is
        still staging (= ingestion mid-flight)."""
        store.begin_epoch(0)
        store.put_block(IngestItem(np.arange(8), Granularity.BLOCK,
                                   (), {}).with_label("chunk", 0), "n0")
        store.commit_epoch(0, n_items=1)

        store.begin_epoch(1)   # mid-flight: staged but not committed
        store.put_block(IngestItem(np.arange(8), Granularity.BLOCK,
                                   (), {}).with_label("chunk", 1), "n1")

        acc = DataAccess(store)
        assert {e.epoch for e in acc.entries} == {0}
        assert len(acc.since_epoch(-1)) == 1
        assert len(acc.filter_epoch(1)) == 0
        assert acc.latest_epoch() == 0

        store.commit_epoch(1, n_items=1)
        acc = DataAccess(store)
        assert len(acc.since_epoch(-1)) == 2
        assert len(acc.since_epoch(0)) == 1
        assert len(acc.filter_epoch(1)) == 1

    def test_manifest_roundtrip_excludes_staged(self, store):
        store.begin_epoch(0)
        store.put_block(IngestItem(np.arange(8), Granularity.BLOCK), "n0")
        store.commit_epoch(0)
        store.begin_epoch(1)
        store.put_block(IngestItem(np.arange(9), Granularity.BLOCK), "n0")
        store.flush_manifest()   # e.g. an UploadOp finalize mid-epoch

        reloaded = DataStore(store.root, nodes=store.nodes)
        assert reloaded.committed_epoch_ids() == [0]
        assert all(e.epoch != 1 for e in reloaded.blocks())
        assert reloaded.epochs[0].n_blocks == 1
        assert reloaded.next_epoch_id() == 1


class TestEpochReplay:
    def test_node_death_replays_epoch_without_loss(self, store):
        """Acceptance demo: unbounded iterator, >=3 epochs, one node death
        mid-stream -> every item readable, no loss, no duplicate commits."""
        n_shards, rows = 16, 100
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8)
        faults = StreamFaultInjection(node_death_in_epoch={"n2": 1})
        rep = eng.run_stream(columnar_plan(store), shard_source(n_shards, rows),
                             faults=faults)

        assert len(rep.epochs) >= 3
        assert rep.node_failures == ["n2"]
        assert rep.replayed_epochs == [1]
        assert rep.epochs[1].attempts == 2          # aborted once, replayed
        # commits are unique (no epoch committed twice)
        ids = rep.committed_epoch_ids()
        assert len(ids) == len(set(ids))
        assert store.committed_epoch_ids() == ids

        # zero loss / zero duplication: row count over epoch-aware access
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == n_shards * rows
        # the dead node took no blocks in any later epoch
        later = [e for e in store.blocks() if e.epoch > 1]
        assert later and all(e.node != "n2" for e in later)

    def test_shuffled_epochs_never_merge_stale_dfs_files(self, store):
        """The shuffle barrier's DFS directory is consumed per round: epoch N+1
        (and an epoch replay after abort) must not re-read epoch N's pickles —
        that would duplicate committed items."""
        from repro.core import chain_stage, resolve_op

        def shuffled_plan():
            p = IngestPlan("shuf")
            s1 = p.add_statement([
                resolve_op("identity_parser"),
                resolve_op("partition", scheme="hash", key="orderkey",
                           num_partitions=4),
                resolve_op("map", fn=lambda cols: cols, shuffle_by="partition"),
            ], kind="select")
            s2 = p.add_statement([
                resolve_op("chunk", target_rows=256),
                resolve_op("serialize", layout="columnar"),
                resolve_op("upload", store=store),
            ], kind="store", inputs=[s1])
            create_stage(p, using=[s1], name="a")
            chain_stage(p, to=["a"], using=[s2], name="b")
            return p

        n_shards, rows = 12, 100
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8)
        faults = StreamFaultInjection(node_death_in_epoch={"n1": 1})
        rep = eng.run_stream(shuffled_plan(), shard_source(n_shards, rows),
                             faults=faults)
        assert len(rep.epochs) == 3 and rep.replayed_epochs == [1]
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == n_shards * rows   # exactly once

    def test_op_failures_still_retry_within_epoch(self, store):
        faults = StreamFaultInjection(op_failures={("main", 0): 2})
        rep = stream_ingest(columnar_plan(store, epoch_items=8),
                            shard_source(8), store, faults=faults)
        runs = [e.run for e in rep.epochs]
        assert any(r.op_failures for r in runs)          # observed
        assert not any(r.dummy_substitutions for r in runs)  # recovered


class TestStreamingLanguage:
    def test_stream_with_epochs_text_surface(self, store):
        plan = parse_ingestion_script(
            """
            s1 = SELECT * FROM input;
            s2 = FORMAT s1 CHUNK BY 1000 SERIALIZE AS columnar;
            s3 = STORE s2 UPLOAD TO target;
            CREATE STAGE main USING s1,s2,s3;
            STREAM WITH EPOCHS(items=4, capacity=16);
            """, env={"target": store})
        assert plan.stream_config == {"items": 4, "capacity": 16}
        assert plan.signature()["stream"] == {"items": 4, "capacity": 16}

        rep = stream_ingest(plan, shard_source(8), store)
        assert len(rep.epochs) == 2   # items=4 came from the script

    def test_bad_stream_clause_rejected(self):
        from repro.core.language import LanguageError
        with pytest.raises(LanguageError):
            parse_ingestion_script("STREAM WITH EPOCHS(bogus=1);")
        with pytest.raises(LanguageError):
            parse_ingestion_script("STREAM EVERY 5;")

    def test_wallclock_tick_cuts_epoch(self, store):
        """A slow source with a wall-clock tick commits partial epochs."""
        def slow_source():
            for i in range(4):
                time.sleep(0.05)
                yield IngestItem(gen_lineitem(50, seed=i))

        p = columnar_plan(store)
        with_epochs(p, items=1000, seconds=0.02)  # tick fires before 1000 items
        rep = stream_ingest(p, slow_source(), store)
        assert rep.total_items == 4
        assert len(rep.epochs) >= 2   # ticks cut the stream into several epochs


class TestFeederTailing:
    def _lm_plan(self, ds):
        from repro.data.feeder import build_lm_plan
        return build_lm_plan(ds, seq_len=64, rows_per_block=4)

    def _doc_source(self, n_docs, seed):
        from repro.data.generators import as_file_items
        docs = gen_token_documents(n_docs, vocab=512, seed=seed, max_len=128)
        return iter(as_file_items(docs, shards=4))

    def test_tail_follows_committed_epochs(self, store):
        from repro.data.feeder import BlockFeeder
        eng = StreamingRuntimeEngine(store, epoch_items=2, queue_capacity=4)
        eng.run_stream(self._lm_plan(store), self._doc_source(12, seed=0))
        feeder = BlockFeeder(store, num_tasks=1, task=0)
        n_before = len(feeder)
        assert n_before > 0

        # more epochs commit after the feeder was built; tail picks them up
        eng.run_stream(self._lm_plan(store), self._doc_source(12, seed=1))
        assert feeder.refresh() > 0
        batches = list(feeder.tail(num_steps=len(feeder), timeout_s=0.5))
        assert len(batches) == len(feeder) > n_before
        assert all("tokens" in b for b in batches)
