"""Direct regression tests for the optimizer's rewrite rules: ReorderRule
legality (field dependencies, replicate never earlier) and FilterFusionRule
(AND semantics, fused selectivity, field union)."""
import numpy as np
import pytest

from repro.core.items import Granularity, IngestItem
from repro.core.operators import MaterializeOp
from repro.core.optimizer import (FilterFusionRule, IngestionOptimizer,
                                  IngestOpExpr, ReorderRule, _commutes)
from repro.core.ops_select import FilterOp, ProjectOp, ReplicateOp


def names(ops):
    return [type(o).__name__ for o in ops if not isinstance(o, MaterializeOp)]


def chunk_item(n=100, seed=0):
    rng = np.random.default_rng(seed)
    return IngestItem({"a": rng.integers(0, 10, n).astype(np.int32),
                       "b": rng.integers(0, 10, n).astype(np.int32)},
                      Granularity.CHUNK)


class TestReorderLegality:
    def test_filter_moves_before_projection_keeping_its_fields(self):
        proj = ProjectOp(fields=("a", "b"))
        filt = FilterOp(predicate=lambda c: c["a"] > 5, fields=("a",),
                        selectivity=0.3)
        out = IngestionOptimizer(rules=[ReorderRule()]).optimize_chain([proj, filt])
        assert names(out) == ["FilterOp", "ProjectOp"]

    def test_filter_never_moves_before_projection_dropping_its_fields(self):
        proj = ProjectOp(fields=("a",))    # drops "b"
        filt = FilterOp(predicate=lambda c: c["b"] > 5, fields=("b",),
                        selectivity=0.3)
        assert not _commutes(proj, filt)
        out = IngestionOptimizer(rules=[ReorderRule()]).optimize_chain([proj, filt])
        assert names(out) == ["ProjectOp", "FilterOp"]

    def test_filter_with_unknown_fields_stays_put(self):
        """A filter that declares no fields may read anything: moving it past
        a projection is never legal."""
        proj = ProjectOp(fields=("a", "b"))
        filt = FilterOp(predicate=lambda c: c["a"] > 5, fields=())
        assert not _commutes(proj, filt)

    def test_replicate_is_never_moved_earlier(self):
        filt = FilterOp(predicate=lambda c: c["a"] > 5, fields=("a",),
                        selectivity=0.9)   # even a weak reducer
        rep = ReplicateOp(copies=2)
        # replicate is the later op: the rule must not pull it forward
        assert not _commutes(filt, rep)
        out = IngestionOptimizer(rules=[ReorderRule()]).optimize_chain([filt, rep])
        assert names(out) == ["FilterOp", "ReplicateOp"]

    def test_reducer_moves_before_replicate(self):
        rep = ReplicateOp(copies=3)
        filt = FilterOp(predicate=lambda c: c["a"] > 5, fields=("a",),
                        selectivity=0.3)
        out = IngestionOptimizer(rules=[ReorderRule()]).optimize_chain([rep, filt])
        assert names(out) == ["FilterOp", "ReplicateOp"]

    def test_reorder_preserves_result_rows(self):
        item = chunk_item()
        proj = ProjectOp(fields=("a", "b"))
        filt = FilterOp(predicate=lambda c: c["a"] > 5, fields=("a",),
                        selectivity=0.3)
        before = filt.clone().run(proj.clone().run([item]))
        after_ops = IngestionOptimizer(rules=[ReorderRule()]).optimize_chain(
            [proj, filt])
        out = [item]
        for op in after_ops:
            out = op.clone().run(out)
        assert before[0].nrows() == out[0].nrows()
        assert sorted(before[0].data) == sorted(out[0].data)


class TestFilterFusion:
    def test_adjacent_filters_fuse_to_and(self):
        f1 = FilterOp(predicate=lambda c: c["a"] > 3, fields=("a",),
                      selectivity=0.6)
        f2 = FilterOp(predicate=lambda c: c["b"] < 7, fields=("b",),
                      selectivity=0.5)
        out = IngestionOptimizer(rules=[FilterFusionRule()]).optimize_chain([f1, f2])
        fused = [o for o in out if isinstance(o, FilterOp)]
        assert len(fused) == 1
        # fused selectivity is the product; fields are the union
        assert fused[0].expansion == pytest.approx(0.3)
        assert set(fused[0].fields) == {"a", "b"}

        item = chunk_item()
        got = fused[0].run([item])[0]
        mask = (item.data["a"] > 3) & (item.data["b"] < 7)
        assert got.nrows() == int(mask.sum())
        np.testing.assert_array_equal(got.data["a"], item.data["a"][mask])

    def test_fusion_chains_to_single_filter(self):
        fs = [FilterOp(predicate=lambda c, t=t: c["a"] != t, fields=("a",),
                       selectivity=0.9) for t in range(4)]
        out = IngestionOptimizer(rules=[FilterFusionRule()]).optimize_chain(fs)
        fused = [o for o in out if isinstance(o, FilterOp)]
        assert len(fused) == 1
        assert fused[0].expansion == pytest.approx(0.9 ** 4)

    def test_fusion_matches_unfused_semantics(self):
        item = chunk_item(n=500, seed=3)
        f1 = FilterOp(predicate=lambda c: c["a"] >= 2, fields=("a",))
        f2 = FilterOp(predicate=lambda c: c["b"] <= 8, fields=("b",))
        unfused = f2.clone().run(f1.clone().run([item]))
        fused_ops = IngestionOptimizer(rules=[FilterFusionRule()]).optimize_chain(
            [f1, f2])
        fused_out = [item]
        for op in fused_ops:
            fused_out = op.run(fused_out)
        np.testing.assert_array_equal(unfused[0].data["a"], fused_out[0].data["a"])
        np.testing.assert_array_equal(unfused[0].data["b"], fused_out[0].data["b"])
